//! The surrogate transformer running natively on prepacked quantized
//! weights.
//!
//! [`PackedModel::build`] prepacks every linear weight **once** under a
//! per-layer quantization assignment; `forward()` quantizes activations
//! per batch and multiplies in the packed code domain. The forward math
//! mirrors `python/compile/model.py` exactly (embed + learned pos,
//! pre-LN blocks, full-precision attention and head per paper App. A,
//! per-tensor γ gains folded around every quantized linear).
//!
//! # Execution paths (decided per layer at build time)
//!
//! * **Packed** — minifloat elements, activations quantized, no eq. 11
//!   per-tensor scaling, contraction dim block-aligned: activations
//!   encode to a [`GemmOperand`] per batch and multiply through
//!   [`PackedGemm`] against the cached weight operand. Bit-identical to
//!   the reference path by the engine's exactness contract (DESIGN.md
//!   §8) — which the serve property suite re-pins end to end.
//!
//! # Tensor-parallel sharding
//!
//! [`PackedModel::build_sharded`] splits every packed-path weight into
//! `shards` block-aligned column shards ([`ShardedOperand`], cached
//! per shard slot in the [`OperandCache`]) and fans each linear's
//! shard matmuls out over a persistent [`ShardPool`] of `shards - 1`
//! marked workers (the calling thread runs shard 0). Because sharding
//! partitions *output columns* and the combine scatters fixed-order
//! panels, sharded logits are bit-identical to `shards = 1` for every
//! entry shape — whole-batch forward, prefill, and the m == 1 decode
//! step all route through the same [`Linear::apply`] (DESIGN.md §12;
//! `rust/tests/shard.rs` pins the invariance differentially). Layers
//! whose output is a single scale block, and the Exact/Reference
//! paths, simply stay unsharded.
//! * **Reference** — INT elements, per-tensor "-S" scaling, or
//!   weight-only quantization: the prepacked weights are the scalar
//!   fake-quant of the transposed tensor, and the GEMM is the f32
//!   [`matmul_t`] reference.
//! * **Exact** — quantization off for this layer (`bf16-exact`):
//!   plain f32 GEMM on stored transposed weights.
//!
//! Set `MICROSCALE_SERVE=reference` to force every layer onto the
//! reference path when bisecting a discrepancy. The variable is
//! **latched** — read once per process at the first layer build and
//! cached (like `MICROSCALE_KERNEL`, `MICROSCALE_GEMM` and
//! `MICROSCALE_SIMD`); set it before the model is built.
//!
//! # Batching invariance
//!
//! A request's logits never depend on its co-batched neighbors: token
//! embedding, LN, GELU and the residual stream are per-position;
//! attention and softmax are per-sequence; GEMM outputs are per-row
//! with a fixed accumulation order; block quantization of activations
//! is per-row (blocks never span rows in the [`GemmOperand`] layout);
//! and the one batch-global statistic in the system — the eq. 11
//! per-tensor absmax — is deliberately computed per *sequence*
//! (`quantize_acts_by_sequence`). `rust/tests/serve.rs` pins the
//! guarantee by re-batching the same request among different neighbors.
//!
//! # One numeric spine: whole-batch, prefill, and decode
//!
//! `forward_spine` is the single implementation behind all three
//! entry shapes. It processes a *ragged* batch — `lens[b]` new tokens
//! for sequence `b`, appended after `kvs[b].len()` positions already
//! resident in that sequence's [`SeqKv`] cache (post-gain keys and
//! values per layer; attention is full precision per paper App. A, so
//! an exact cache holds exactly what the whole-batch pass would have
//! computed — [`SeqKv`] docs describe the inline f32 backend and the
//! paged backend with its `Exact`/`Mx` page codecs).
//! [`PackedModel::forward`] is the `past = 0`, equal-`lens`
//! special case; prefill is one sequence with `past = 0`; a decode step
//! is `lens = [1, 1, ...]` over live caches ([`crate::serve::decode`]).
//!
//! The KV-cached step is **bit-identical** to re-running the full
//! prefix because every reduction keeps a fixed order: the attention
//! dot `Σ_t q[t]·k[t]` and the value mix `Σ_j a[j]·v[j]` run in
//! ascending `t`/`j` exactly as the whole-batch loop ran them (cache
//! row `j` holds the same bits row `j` of the whole-batch K/V GEMM
//! produced, by the per-row GEMM contract), softmax normalizes over the
//! same `j = 0..=i` span, and LN/GELU/residual are per-row. The one
//! construct this argument cannot cover is per-tensor "-S" *activation*
//! scaling, whose eq. 11 absmax spans the whole prefix — the decode
//! engine refuses those configs up front. `rust/tests/decode.rs` pins
//! step-by-step bit-equality against [`reference_forward`] re-run on
//! the full prefix at every generated token.

use std::sync::Arc;

use anyhow::ensure;

use crate::formats::ElemFormat;
use crate::model::weights::Params;
use crate::quant::gemm::{GemmOperand, PackedGemm};
use crate::quant::matmul::{matmul_t, transpose};
use crate::quant::rotate::{fwht_rows, fwht_rows_transposed};
use crate::quant::shard::{shard_ranges, ShardedOperand};
use crate::quant::{QuantKernel, QuantScheme, ScalarKernel};
use crate::util::par::ShardPool;
use crate::runtime::artifacts::ModelDims;
use crate::runtime::qconfig::{PerLayerQConfig, QConfig};

use super::cache::OperandCache;

/// How one linear layer executes at serve time.
enum LinearPath {
    /// Quantization off: plain f32 GEMM on stored transposed weights.
    Exact { wt: Vec<f32> },
    /// Code-domain path: prepacked weight operand in 1..=N block-aligned
    /// column shards (each shared through the [`OperandCache`]),
    /// activations quantized per batch.
    Packed { ops: ShardedOperand },
    /// Scalar fake-quant fallback: prepacked fake-quantized transposed
    /// weights + f32 reference GEMM.
    Reference { wt_q: Vec<f32> },
}

/// One prepacked linear (`y = x @ w`, weights stored transposed).
struct Linear {
    path: LinearPath,
    cfg: QConfig,
    /// `Some` whenever quantization is on for this layer.
    scheme: Option<QuantScheme>,
    k: usize,
    n: usize,
}

impl Linear {
    fn build(
        cfg: &QConfig,
        block_size: usize,
        w: &[f32],
        k: usize,
        n: usize,
        cache: &OperandCache,
        shards: usize,
    ) -> crate::Result<Linear> {
        if !cfg.quant_on {
            // rotation is *elided* on exact layers: `xHHᵀW = xW` holds in
            // the algebra, so skipping both transforms is the only way to
            // stay bit-identical to the unrotated exact path (f32 FWHT
            // round-trips are not bit-exact) — DESIGN.md §16
            return Ok(Linear {
                path: LinearPath::Exact { wt: transpose(w, k, n) },
                cfg: *cfg,
                scheme: None,
                k,
                n,
            });
        }
        let scheme = cfg.scheme(block_size);
        if let Some(bs) = cfg.bs_override {
            // the model-global block size is validated against the model
            // dims once at build_sharded; a per-layer override must make
            // the same guarantee for this layer's contraction dim
            ensure!(
                bs > 0 && k % bs == 0,
                "per-layer block size {bs} must divide contraction dim {k}"
            );
        }
        // latched: read once per process (Linear::build runs per layer
        // per model build, and model rebuilds happen inside sweeps).
        // Set MICROSCALE_SERVE before the first build; changes after
        // that are ignored.
        static FORCED_REF: std::sync::OnceLock<bool> =
            std::sync::OnceLock::new();
        let forced_ref = *FORCED_REF.get_or_init(|| {
            std::env::var("MICROSCALE_SERVE").as_deref() == Ok("reference")
        });
        // the packed engine is used only where it is provably
        // bit-identical to the reference (minifloat elements, no eq. 11
        // pre-scaling, both operands quantized, aligned contraction)
        let packed_ok = !forced_ref
            && cfg.act_quant
            && !scheme.per_tensor
            && matches!(scheme.elem, ElemFormat::Fp(_))
            && k % scheme.block_size == 0;
        let rotate = cfg.rotate;
        let path = if packed_ok {
            // effective shard count degrades with the layer's output
            // width (shard_ranges caps at whole column blocks); each
            // shard is its own cache entry, keyed by shard slot (and by
            // the rotation flag: a rotated weight operand holds `HW`,
            // the folded weight-side half of the rotated GEMM)
            let ranges = shard_ranges(n, scheme.block_size, shards);
            let ops = if ranges.len() <= 1 {
                ShardedOperand::single(if rotate {
                    cache.get_or_pack_transposed_rotated(&scheme, w, k, n)?
                } else {
                    cache.get_or_pack_transposed(&scheme, w, k, n)?
                })
            } else {
                let count = ranges.len();
                let mut parts = Vec::with_capacity(count);
                for (i, &(c0, c1)) in ranges.iter().enumerate() {
                    parts.push(if rotate {
                        cache.get_or_pack_transposed_shard_rotated(
                            &scheme, w, k, n, i, count, c0, c1,
                        )?
                    } else {
                        cache.get_or_pack_transposed_shard(
                            &scheme, w, k, n, i, count, c0, c1,
                        )?
                    });
                }
                ShardedOperand::from_parts(parts, ranges)?
            };
            LinearPath::Packed { ops }
        } else {
            let mut wt = transpose(w, k, n);
            if rotate {
                // each transposed row is one output channel's k-vector
                // over the contraction dim — rotating rows here equals
                // transpose(fwht_cols(w)) bit for bit
                fwht_rows_transposed(&mut wt, k);
            }
            LinearPath::Reference {
                wt_q: ScalarKernel.fake_quant(&scheme, &wt),
            }
        };
        Ok(Linear { path, cfg: *cfg, scheme: Some(scheme), k, n })
    }

    /// `x` is row-major `rows × k` (rows = Σ lens); returns `rows × n`.
    /// `lens` gives each sequence's row count, bounding the
    /// per-sequence quantization chunks (ragged batches are fine).
    fn apply(
        &self,
        x: &[f32],
        rows: usize,
        lens: &[usize],
        gemm: &PackedGemm,
        pool: Option<&ShardPool>,
    ) -> crate::Result<Vec<f32>> {
        debug_assert_eq!(x.len(), rows * self.k);
        // activation-side half of the rotated GEMM: `x → xH` per row,
        // before quantization, on the quantized paths only (exact
        // layers elide rotation entirely — see Linear::build). The
        // rotation is per-row, so batching invariance and the
        // decode/ragged bit-identity argument survive unchanged.
        let rotated: Option<Vec<f32>> =
            (self.cfg.rotate && self.cfg.quant_on).then(|| {
                let mut xr = x.to_vec();
                fwht_rows(&mut xr, self.k);
                xr
            });
        let x = rotated.as_deref().unwrap_or(x);
        match &self.path {
            LinearPath::Exact { wt } => {
                Ok(matmul_t(x, wt, rows, self.k, self.n))
            }
            LinearPath::Packed { ops } => {
                let scheme = self.scheme.as_ref().unwrap();
                let xo = GemmOperand::quantize(scheme, x, rows, self.k)?;
                ops.matmul(xo, gemm, pool)
            }
            LinearPath::Reference { wt_q } => {
                let scheme = self.scheme.as_ref().unwrap();
                if self.cfg.act_quant {
                    let xq = quantize_acts_by_sequence(
                        scheme, x, rows, lens, self.k,
                    );
                    Ok(matmul_t(&xq, wt_q, rows, self.k, self.n))
                } else {
                    Ok(matmul_t(x, wt_q, rows, self.k, self.n))
                }
            }
        }
    }
}

/// Counts of layers on each execution path (build diagnostics).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PathSummary {
    pub exact: usize,
    pub packed: usize,
    pub reference: usize,
}

/// One sequence's KV cache: per layer, one key row and one value row
/// per resident position, stored **post-gain** (the bits the
/// whole-batch K/V GEMMs + γ scaling produce — attention itself is full
/// precision per paper App. A).
///
/// Two storage backends share this type:
///
/// * **Inline** ([`SeqKv::new`] / [`SeqKv::with_capacity`]) — plain
///   per-layer `Vec<f32>` rows, read zero-copy by the spine. Always
///   bit-exact; this is the PR-4 layout and what scratch caches use.
/// * **Paged** ([`crate::serve::KvPool::seq`]) — rows live in
///   fixed-size pages allocated from a byte-budgeted
///   [`crate::serve::KvPool`] and pass through the pool's per-layer
///   page codec: `Exact` pages round-trip f32 bits unchanged (the
///   decode exactness contract holds verbatim), `Mx` pages store
///   block-quantized codes + scales and read back as
///   `fake_quant(scheme, row)` — the stated error model
///   ([`crate::serve::kvpool`] module docs).
///
/// Rows append in position order; [`SeqKv::len`] is the number of
/// resident positions.
#[derive(Debug, Default)]
pub struct SeqKv {
    store: Store,
    len: usize,
}

#[derive(Debug)]
enum Store {
    Inline { k: Vec<Vec<f32>>, v: Vec<Vec<f32>> },
    Paged(super::kvpool::PagedKv),
}

impl Default for Store {
    fn default() -> Store {
        Store::Inline { k: Vec::new(), v: Vec::new() }
    }
}

impl SeqKv {
    /// Empty inline cache for an `n_layers`-deep model.
    pub fn new(n_layers: usize) -> SeqKv {
        SeqKv {
            store: Store::Inline {
                k: vec![Vec::new(); n_layers],
                v: vec![Vec::new(); n_layers],
            },
            len: 0,
        }
    }

    /// Empty inline cache with room for `positions` rows of width
    /// `d_model` per layer (decode appends one row per step — reserve
    /// once).
    pub fn with_capacity(
        n_layers: usize,
        d_model: usize,
        positions: usize,
    ) -> SeqKv {
        let mk = || {
            (0..n_layers)
                .map(|_| Vec::with_capacity(positions * d_model))
                .collect()
        };
        SeqKv { store: Store::Inline { k: mk(), v: mk() }, len: 0 }
    }

    /// Wrap a pool-backed cache ([`crate::serve::KvPool::seq`]).
    pub(crate) fn paged(p: super::kvpool::PagedKv) -> SeqKv {
        SeqKv { store: Store::Paged(p), len: 0 }
    }

    /// The paged backend, when there is one (pool-internal hooks:
    /// prefix pinning).
    pub(crate) fn as_paged(&self) -> Option<&super::kvpool::PagedKv> {
        match &self.store {
            Store::Inline { .. } => None,
            Store::Paged(p) => Some(p),
        }
    }

    /// Truncate the cache to its first `positions` rows per layer — the
    /// speculative-decode rollback that discards rejected draft rows
    /// (no-op when `positions >= len`). Inline caches shrink their row
    /// vectors; paged caches free whole pages past the cut and
    /// privatize a shared tail page ([`crate::serve::kvpool`] docs).
    /// A paged privatizing copy can fail on budget exhaustion; the
    /// cache must then be [`SeqKv::reset`] before further appends.
    pub fn truncate(&mut self, positions: usize) -> crate::Result<()> {
        if positions >= self.len {
            return Ok(());
        }
        match &mut self.store {
            Store::Inline { k, v } => {
                for rows in k.iter_mut().chain(v.iter_mut()) {
                    // every layer holds len rows of equal width
                    let d = rows.len() / self.len;
                    rows.truncate(positions * d);
                }
            }
            Store::Paged(p) => p.truncate(positions)?,
        }
        self.len = positions;
        Ok(())
    }

    /// Resident positions.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Layers this cache was shaped for.
    pub fn layers(&self) -> usize {
        match &self.store {
            Store::Inline { k, .. } => k.len(),
            Store::Paged(p) => p.layers(),
        }
    }

    /// Whether this cache is backed by a [`crate::serve::KvPool`].
    pub fn is_paged(&self) -> bool {
        matches!(self.store, Store::Paged(_))
    }

    /// The backing pool, when paged.
    pub fn pool(&self) -> Option<&std::sync::Arc<super::kvpool::KvPool>> {
        match &self.store {
            Store::Inline { .. } => None,
            Store::Paged(p) => Some(p.pool()),
        }
    }

    /// Resident bytes: the f32 payload for inline caches, the exact
    /// allocated page bytes (partially filled pages included) for
    /// paged ones.
    pub fn resident_bytes(&self) -> usize {
        match &self.store {
            Store::Inline { k, v } => k
                .iter()
                .chain(v.iter())
                .map(|rows| rows.len() * std::mem::size_of::<f32>())
                .sum(),
            Store::Paged(p) => p.resident_bytes(),
        }
    }

    /// Release the cache's storage (paged: pages return to the pool)
    /// and return to the empty state — the scheduler's eviction
    /// primitive.
    pub fn reset(&mut self) {
        match &mut self.store {
            Store::Inline { k, v } => {
                for rows in k.iter_mut().chain(v.iter_mut()) {
                    rows.clear();
                }
            }
            Store::Paged(p) => p.reset(),
        }
        self.len = 0;
    }

    /// Clone this cache's resident prefix into an independent cache.
    /// Inline caches copy their rows; paged caches share full pages by
    /// refcount bump and deep-copy only partial tail pages
    /// ([`crate::serve::kvpool`] module docs — shared pages are
    /// immutable, so divergence after the fork never touches them).
    /// Paged forks are priced against the pool budget atomically.
    pub fn fork(&self) -> crate::Result<SeqKv> {
        let store = match &self.store {
            Store::Inline { k, v } => {
                Store::Inline { k: k.clone(), v: v.clone() }
            }
            Store::Paged(p) => Store::Paged(p.fork()?),
        };
        Ok(SeqKv { store, len: self.len })
    }

    /// One layer's resident K and V rows, decoded to dense f32
    /// (`len · d_model` each) — the KV sweep's trace-capture hook and a
    /// debugging aid. Inline caches copy; paged caches decode through
    /// their codec.
    pub fn layer_rows_f32(&self, layer: usize) -> (Vec<f32>, Vec<f32>) {
        match &self.store {
            Store::Inline { k, v } => (k[layer].clone(), v[layer].clone()),
            Store::Paged(p) => {
                let (mut k, mut v) = (Vec::new(), Vec::new());
                p.gather(layer, &mut k, &mut v);
                (k, v)
            }
        }
    }

    /// Shape/consistency validation the spine runs per call: layer
    /// count, per-layer row payloads == `len` (catches caches reused
    /// after a failed partial step), and — for paged caches — that the
    /// pool was built for this model's width.
    fn validate_for(&self, dims: &ModelDims) -> crate::Result<()> {
        let d = dims.d_model;
        ensure!(
            self.layers() == dims.n_layers,
            "KV cache has {} layers, model has {}",
            self.layers(),
            dims.n_layers
        );
        match &self.store {
            Store::Inline { k, v } => {
                for (li, kl) in k.iter().enumerate() {
                    ensure!(
                        kl.len() == self.len * d && v[li].len() == self.len * d,
                        "KV cache layer {li} holds {}/{} values for {} \
                         positions of width {d} — reused after a failed step?",
                        kl.len(),
                        v[li].len(),
                        self.len
                    );
                }
            }
            Store::Paged(p) => {
                ensure!(
                    p.pool().d_model() == d,
                    "KV pool pages are {} wide, model d_model is {d}",
                    p.pool().d_model()
                );
                for li in 0..p.layers() {
                    let (kr, vr) = p.rows(li);
                    ensure!(
                        kr == self.len && vr == self.len,
                        "KV cache layer {li} holds {kr}/{vr} rows for {} \
                         positions — reused after a failed step?",
                        self.len
                    );
                }
            }
        }
        Ok(())
    }

    /// Append one layer's new post-gain K/V rows (paged caches may fail
    /// on pool-budget exhaustion — callers reserve first).
    fn append_layer(
        &mut self,
        layer: usize,
        ky: &[f32],
        vv: &[f32],
    ) -> crate::Result<()> {
        match &mut self.store {
            Store::Inline { k, v } => {
                k[layer].extend_from_slice(ky);
                v[layer].extend_from_slice(vv);
                Ok(())
            }
            Store::Paged(p) => p.append(layer, ky, vv),
        }
    }

    /// One layer's resident rows for the attention loop. Inline caches
    /// return their rows zero-copy; paged caches decode into the
    /// caller's scratch buffers (`code_scratch` carries the element
    /// codes, so the per-token read allocates nothing).
    fn layer_rows<'a>(
        &'a self,
        layer: usize,
        k_scratch: &'a mut Vec<f32>,
        v_scratch: &'a mut Vec<f32>,
        code_scratch: &mut Vec<u8>,
    ) -> (&'a [f32], &'a [f32]) {
        match &self.store {
            Store::Inline { k, v } => (k[layer].as_slice(), v[layer].as_slice()),
            Store::Paged(p) => {
                p.gather_with(layer, k_scratch, v_scratch, code_scratch);
                (k_scratch.as_slice(), v_scratch.as_slice())
            }
        }
    }
}

/// The prepacked surrogate transformer (see module docs).
pub struct PackedModel {
    dims: ModelDims,
    qcfg: PerLayerQConfig,
    block_size: usize,
    gemm: PackedGemm,
    embed: Vec<f32>,
    pos: Vec<f32>,
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
    gains: Vec<f32>,
    /// Transposed unquantized head, `(vocab, d_model)` (paper App. A).
    head_t: Vec<f32>,
    /// `n_layers × 6` linears in [`Params::QUANTIZED`] order.
    linears: Vec<Linear>,
    /// Configured tensor-parallel shard count (1 = unsharded).
    shards: usize,
    /// Persistent shard workers (`shards - 1` threads), present iff
    /// `shards > 1`; `Arc` so engines/tests can share or swap pools.
    shard_pool: Option<Arc<ShardPool>>,
}

/// Contraction/output dims of quantized linear `which`
/// ([`Params::QUANTIZED`] order: wq wk wv wo w1 w2).
fn linear_dims(dims: &ModelDims, which: usize) -> (usize, usize) {
    let (d, f) = (dims.d_model, dims.d_ff);
    match which {
        4 => (d, f), // w1
        5 => (f, d), // w2
        _ => (d, d), // wq wk wv wo
    }
}

impl PackedModel {
    /// Prepack `params` under the per-layer config. Every linear weight
    /// encodes exactly once; packed operands are shared through `cache`,
    /// so sessions over the same (tensor, qconfig) pairs reuse one
    /// encode.
    pub fn build(
        dims: &ModelDims,
        params: &Params,
        qcfg: &PerLayerQConfig,
        block_size: usize,
        cache: &OperandCache,
    ) -> crate::Result<PackedModel> {
        PackedModel::build_sharded(dims, params, qcfg, block_size, cache, 1)
    }

    /// [`PackedModel::build`] with every packed-path weight split into
    /// `shards` block-aligned column shards, multiplied concurrently on
    /// a dedicated [`ShardPool`] (module docs). `shards = 1` is exactly
    /// `build`; any `N > 1` produces bit-identical logits to `N = 1`
    /// for every entry shape.
    pub fn build_sharded(
        dims: &ModelDims,
        params: &Params,
        qcfg: &PerLayerQConfig,
        block_size: usize,
        cache: &OperandCache,
        shards: usize,
    ) -> crate::Result<PackedModel> {
        ensure!(shards > 0, "shard count must be positive");
        ensure!(block_size > 0, "block size must be positive");
        ensure!(
            dims.n_heads > 0 && dims.d_model % dims.n_heads == 0,
            "d_model {} not divisible by n_heads {}",
            dims.d_model,
            dims.n_heads
        );
        ensure!(
            dims.d_model % block_size == 0 && dims.d_ff % block_size == 0,
            "block size {block_size} must divide d_model {} and d_ff {}",
            dims.d_model,
            dims.d_ff
        );
        let (l, d, f, v, s) =
            (dims.n_layers, dims.d_model, dims.d_ff, dims.vocab, dims.seq_len);
        let get = |name: &str, want: usize| -> crate::Result<Vec<f32>> {
            let (_, data) = params.get(name)?;
            ensure!(
                data.len() == want,
                "tensor {name}: {} elements, want {want}",
                data.len()
            );
            Ok(data.to_vec())
        };
        let head = get("head", d * v)?;
        let mut linears = Vec::with_capacity(l * 6);
        for layer in 0..l {
            let cfg = qcfg.layer(layer);
            for (which, name) in Params::QUANTIZED.iter().enumerate() {
                let (kd, nd) = linear_dims(dims, which);
                let (_, data) = params.get(name)?;
                let per = kd * nd;
                ensure!(
                    data.len() == l * per,
                    "tensor {name}: {} elements, want {l}x{per}",
                    data.len()
                );
                let w = &data[layer * per..(layer + 1) * per];
                linears.push(Linear::build(
                    &cfg, block_size, w, kd, nd, cache, shards,
                )?);
            }
        }
        let shard_pool =
            (shards > 1).then(|| Arc::new(ShardPool::new(shards - 1)));
        Ok(PackedModel {
            dims: *dims,
            qcfg: qcfg.clone(),
            block_size,
            gemm: PackedGemm::auto(),
            embed: get("embed", v * d)?,
            pos: get("pos", s * d)?,
            ln1_g: get("ln1_g", l * d)?,
            ln1_b: get("ln1_b", l * d)?,
            ln2_g: get("ln2_g", l * d)?,
            ln2_b: get("ln2_b", l * d)?,
            lnf_g: get("lnf_g", d)?,
            lnf_b: get("lnf_b", d)?,
            gains: get("gains", l * 6)?,
            head_t: transpose(&head, d, v),
            linears,
            shards,
            shard_pool,
        })
    }

    /// Override the GEMM engine configuration (benches pin
    /// [`PackedGemm::serial`] for the single-thread baseline).
    pub fn with_gemm(mut self, gemm: PackedGemm) -> PackedModel {
        self.gemm = gemm;
        self
    }

    /// Override the shard-worker pool, e.g. to share one pool across
    /// models or to size workers independently of the shard count
    /// (tests pin that pools larger than the shard count stay
    /// bit-exact and never oversubscribe — every shard slot runs its
    /// inner kernel serially regardless of pool size).
    pub fn with_shard_pool(mut self, pool: Arc<ShardPool>) -> PackedModel {
        self.shard_pool = Some(pool);
        self
    }

    /// Configured tensor-parallel shard count (1 = unsharded). Layers
    /// narrower than `shards` column blocks hold fewer effective
    /// shards.
    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn dims(&self) -> &ModelDims {
        &self.dims
    }

    pub fn qcfg(&self) -> &PerLayerQConfig {
        &self.qcfg
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// How many linears landed on each execution path.
    pub fn path_summary(&self) -> PathSummary {
        let mut s = PathSummary::default();
        for lin in &self.linears {
            match lin.path {
                LinearPath::Exact { .. } => s.exact += 1,
                LinearPath::Packed { .. } => s.packed += 1,
                LinearPath::Reference { .. } => s.reference += 1,
            }
        }
        s
    }

    /// Total prepacked wire bytes across the packed-path weights.
    pub fn packed_weight_bytes(&self) -> usize {
        self.linears
            .iter()
            .map(|lin| match &lin.path {
                LinearPath::Packed { ops } => ops.payload_bytes(),
                _ => 0,
            })
            .sum()
    }

    /// Logits (`batch · seq · vocab`, row-major) for `batch` sequences
    /// of `seq` tokens each (`tokens.len() == batch · seq`,
    /// `1 <= seq <= dims.seq_len`) — the `past = 0` special case of
    /// [`PackedModel::forward_ragged`] over scratch caches.
    pub fn forward(
        &self,
        tokens: &[i32],
        batch: usize,
        seq: usize,
    ) -> crate::Result<Vec<f32>> {
        ensure!(batch > 0, "empty batch");
        let lens = vec![seq; batch];
        // scratch caches sized up front: the spine appends seq rows per
        // layer, and growth reallocations on the one-shot serving hot
        // path would be pure waste
        let mut kvs: Vec<SeqKv> = (0..batch)
            .map(|_| SeqKv::with_capacity(self.dims.n_layers, self.dims.d_model, seq))
            .collect();
        self.forward_ragged(tokens, &lens, &mut kvs, false)
    }

    /// A KV cache shaped for this model, with capacity for a full
    /// `seq_len`-position sequence.
    pub fn new_kv(&self) -> SeqKv {
        SeqKv::with_capacity(
            self.dims.n_layers,
            self.dims.d_model,
            self.dims.seq_len,
        )
    }

    /// Incremental ragged forward: `lens[b]` new tokens for sequence
    /// `b` (concatenated in `tokens`), each appended after the
    /// `kvs[b].len()` positions already resident in its cache. Caches
    /// gain the new positions' keys/values. Returns all new rows'
    /// logits (`Σ lens × vocab`), or — with `last_only` — one row per
    /// sequence (`batch × vocab`, each sequence's final new position).
    ///
    /// Bit-identical to re-running the full prefix (module docs) for
    /// every configuration **except** per-tensor "-S" *activation*
    /// scaling, whose eq. 11 absmax spans the whole prefix — a span an
    /// incremental call never sees, so its chunks quantize under a
    /// different factor. [`crate::serve::decode::DecodeEngine::new`]
    /// refuses those configs; callers driving this API directly must
    /// apply the same rule to keep the guarantee. For caches on a
    /// [`crate::serve::KvPool`] the guarantee is per codec: `Exact`
    /// pages keep it verbatim, `Mx` pages make attention read
    /// block-quantized K/V (a stated error model), but incremental and
    /// whole-prefix calls still agree bit for bit *under the same
    /// codec* ([`crate::serve::kvpool`] docs). On error the caches may
    /// hold a partial step — discard them (paged caches additionally
    /// fail when the pool budget is exhausted; schedulers reserve pages
    /// first via [`crate::serve::KvPool::bytes_for_rows`]).
    pub fn forward_ragged(
        &self,
        tokens: &[i32],
        lens: &[usize],
        kvs: &mut [SeqKv],
        last_only: bool,
    ) -> crate::Result<Vec<f32>> {
        let ctx = self.ctx();
        let pool = self.shard_pool.as_deref();
        forward_spine(&ctx, tokens, lens, kvs, last_only, |layer, which, x, rows| {
            self.linears[layer * 6 + which]
                .apply(x, rows, lens, &self.gemm, pool)
        })
    }

    fn ctx(&self) -> Ctx<'_> {
        Ctx {
            dims: &self.dims,
            embed: &self.embed,
            pos: &self.pos,
            ln1_g: &self.ln1_g,
            ln1_b: &self.ln1_b,
            ln2_g: &self.ln2_g,
            ln2_b: &self.ln2_b,
            lnf_g: &self.lnf_g,
            lnf_b: &self.lnf_b,
            gains: &self.gains,
            head_t: &self.head_t,
        }
    }
}

/// The non-GEMM tensors a forward pass reads — shared verbatim between
/// [`PackedModel::forward`] and [`reference_forward`] so bit-exactness
/// of the whole pass reduces to bit-exactness of the linears.
struct Ctx<'a> {
    dims: &'a ModelDims,
    embed: &'a [f32],
    pos: &'a [f32],
    ln1_g: &'a [f32],
    ln1_b: &'a [f32],
    ln2_g: &'a [f32],
    ln2_b: &'a [f32],
    lnf_g: &'a [f32],
    lnf_b: &'a [f32],
    gains: &'a [f32],
    head_t: &'a [f32],
}

/// The scalar fake-quant reference forward: identical math to
/// [`PackedModel::forward`] with every linear on the
/// [`ScalarKernel`]-quantized f32 path, recomputed from raw `params` on
/// each call (no prepacking, no packed engine anywhere). The serve test
/// suite pins the packed model bit-identical to this.
pub fn reference_forward(
    params: &Params,
    dims: &ModelDims,
    qcfg: &PerLayerQConfig,
    block_size: usize,
    tokens: &[i32],
    batch: usize,
    seq: usize,
) -> crate::Result<Vec<f32>> {
    ensure!(batch > 0, "empty batch");
    let (d, v) = (dims.d_model, dims.vocab);
    let head_t = transpose(params.get("head")?.1, d, v);
    let ctx = Ctx {
        dims,
        embed: params.get("embed")?.1,
        pos: params.get("pos")?.1,
        ln1_g: params.get("ln1_g")?.1,
        ln1_b: params.get("ln1_b")?.1,
        ln2_g: params.get("ln2_g")?.1,
        ln2_b: params.get("ln2_b")?.1,
        lnf_g: params.get("lnf_g")?.1,
        lnf_b: params.get("lnf_b")?.1,
        gains: params.get("gains")?.1,
        head_t: &head_t,
    };
    let lens = vec![seq; batch];
    let mut kvs: Vec<SeqKv> = (0..batch)
        .map(|_| SeqKv::with_capacity(dims.n_layers, d, seq))
        .collect();
    forward_spine(&ctx, tokens, &lens, &mut kvs, false, |layer, which, x, rows| {
        let cfg = qcfg.layer(layer);
        let (kd, nd) = linear_dims(dims, which);
        let data = params.get(Params::QUANTIZED[which])?.1;
        let w = &data[layer * kd * nd..(layer + 1) * kd * nd];
        let mut wt = transpose(w, kd, nd);
        if !cfg.quant_on {
            // rotation elided on exact layers, exactly as Linear::build
            return Ok(matmul_t(x, &wt, rows, kd, nd));
        }
        let scheme = cfg.scheme(block_size);
        // the same pre-rotation calls the packed path makes, in the
        // same order, so the packed==reference bit contract holds with
        // rotation on
        let rotated: Option<Vec<f32>> = cfg.rotate.then(|| {
            fwht_rows_transposed(&mut wt, kd);
            let mut xr = x.to_vec();
            fwht_rows(&mut xr, kd);
            xr
        });
        let x = rotated.as_deref().unwrap_or(x);
        let wt_q = ScalarKernel.fake_quant(&scheme, &wt);
        if cfg.act_quant {
            let xq = quantize_acts_by_sequence(&scheme, x, rows, &lens, kd);
            Ok(matmul_t(&xq, &wt_q, rows, kd, nd))
        } else {
            Ok(matmul_t(x, &wt_q, rows, kd, nd))
        }
    })
}

/// Run an **exact** (quantization-off) forward over `params` and record
/// the input activations of every quantized linear: index
/// `layer * 6 + which` ([`Params::QUANTIZED`] order) holds that
/// linear's row-major `rows × k` input. The tuner's calibration hook —
/// per-layer quantization error is measured on exactly the tensors the
/// serving path would quantize (post-LN, post-GELU, post-attention),
/// not on synthetic Gaussians.
pub fn capture_linear_inputs(
    params: &Params,
    dims: &ModelDims,
    tokens: &[i32],
    batch: usize,
    seq: usize,
) -> crate::Result<Vec<Vec<f32>>> {
    ensure!(batch > 0, "empty batch");
    let (d, v) = (dims.d_model, dims.vocab);
    let head_t = transpose(params.get("head")?.1, d, v);
    let ctx = Ctx {
        dims,
        embed: params.get("embed")?.1,
        pos: params.get("pos")?.1,
        ln1_g: params.get("ln1_g")?.1,
        ln1_b: params.get("ln1_b")?.1,
        ln2_g: params.get("ln2_g")?.1,
        ln2_b: params.get("ln2_b")?.1,
        lnf_g: params.get("lnf_g")?.1,
        lnf_b: params.get("lnf_b")?.1,
        gains: params.get("gains")?.1,
        head_t: &head_t,
    };
    let lens = vec![seq; batch];
    let mut kvs: Vec<SeqKv> = (0..batch)
        .map(|_| SeqKv::with_capacity(dims.n_layers, d, seq))
        .collect();
    let mut captures: Vec<Vec<f32>> = vec![Vec::new(); dims.n_layers * 6];
    forward_spine(
        &ctx,
        tokens,
        &lens,
        &mut kvs,
        false,
        |layer, which, x, rows| {
            captures[layer * 6 + which].extend_from_slice(x);
            let (kd, nd) = linear_dims(dims, which);
            let data = params.get(Params::QUANTIZED[which])?.1;
            let w = &data[layer * kd * nd..(layer + 1) * kd * nd];
            let wt = transpose(w, kd, nd);
            Ok(matmul_t(x, &wt, rows, kd, nd))
        },
    )?;
    Ok(captures)
}

/// Fake-quantize a `rows × k` activation matrix one sequence at a time
/// (`lens[b]` rows per chunk, ragged batches included). For per-tensor
/// "-S" schemes the eq. 11 absmax then spans a single request, never
/// its co-batched neighbors — the batching-invariance guarantee. For
/// plain block schemes (`k % bs == 0`, blocks within rows) chunking
/// changes nothing.
fn quantize_acts_by_sequence(
    scheme: &QuantScheme,
    x: &[f32],
    rows: usize,
    lens: &[usize],
    k: usize,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(lens.iter().sum::<usize>(), rows);
    let mut out = x.to_vec();
    let mut r0 = 0usize;
    for &l in lens {
        crate::quant::fake_quant_into(scheme, &mut out[r0 * k..(r0 + l) * k]);
        r0 += l;
    }
    out
}

/// The shared forward skeleton behind whole-batch, prefill, and decode
/// (module docs): everything except the quantized linears, which are
/// injected as `linear(layer, which, x, rows) -> rows × n`. Appends the
/// new positions' post-gain K/V rows to `kvs` and bumps each cache's
/// `len` on success.
fn forward_spine<L>(
    ctx: &Ctx,
    tokens: &[i32],
    lens: &[usize],
    kvs: &mut [SeqKv],
    last_only: bool,
    mut linear: L,
) -> crate::Result<Vec<f32>>
where
    L: FnMut(usize, usize, &[f32], usize) -> crate::Result<Vec<f32>>,
{
    let dims = ctx.dims;
    let (d, v, nh) = (dims.d_model, dims.vocab, dims.n_heads);
    let hd = d / nh;
    let batch = lens.len();
    ensure!(batch > 0, "empty batch");
    ensure!(
        kvs.len() == batch,
        "{} KV caches for {batch} sequences",
        kvs.len()
    );
    let mut rows = 0usize;
    let mut max_ctx = 0usize;
    for (b, (&l, kv)) in lens.iter().zip(kvs.iter()).enumerate() {
        ensure!(l >= 1, "sequence {b}: empty token span");
        // shape + row-payload validation — catches caches reused after
        // a failed (partial) step and caches built against a different
        // model, both of which would otherwise silently misalign the
        // attention reads
        kv.validate_for(dims)
            .map_err(|e| anyhow::anyhow!("sequence {b}: {e}"))?;
        ensure!(
            kv.len + l <= dims.seq_len,
            "sequence {b}: {} cached + {l} new positions exceed seq_len {}",
            kv.len,
            dims.seq_len
        );
        rows += l;
        max_ctx = max_ctx.max(kv.len + l);
    }
    ensure!(
        tokens.len() == rows,
        "token count {} != sum of spans {rows}",
        tokens.len()
    );
    for &t in tokens {
        ensure!(
            t >= 0 && (t as usize) < v,
            "token {t} out of vocab range 0..{v}"
        );
    }
    let pasts: Vec<usize> = kvs.iter().map(|kv| kv.len).collect();

    // x = embed[tokens] + pos[past..past+len] per sequence
    let mut x = vec![0.0f32; rows * d];
    {
        let mut r = 0usize;
        for (b, &l) in lens.iter().enumerate() {
            for i in 0..l {
                let tok = tokens[r] as usize;
                let p = pasts[b] + i;
                let e = &ctx.embed[tok * d..(tok + 1) * d];
                let pp = &ctx.pos[p * d..(p + 1) * d];
                let xr = &mut x[r * d..(r + 1) * d];
                for c in 0..d {
                    xr[c] = e[c] + pp[c];
                }
                r += 1;
            }
        }
    }

    let att_scale = 1.0 / (hd as f32).sqrt();
    let mut att = vec![0.0f32; max_ctx];
    // scratch for paged caches (inline caches are read zero-copy)
    let mut k_scratch: Vec<f32> = Vec::new();
    let mut v_scratch: Vec<f32> = Vec::new();
    let mut code_scratch: Vec<u8> = Vec::new();
    for layer in 0..dims.n_layers {
        let g = &ctx.gains[layer * 6..(layer + 1) * 6];
        let h1 = layer_norm(
            &x,
            &ctx.ln1_g[layer * d..(layer + 1) * d],
            &ctx.ln1_b[layer * d..(layer + 1) * d],
            d,
        );
        let q = scaled(linear(layer, 0, &h1, rows)?, g[0]);
        let ky = scaled(linear(layer, 1, &h1, rows)?, g[1]);
        let vv = scaled(linear(layer, 2, &h1, rows)?, g[2]);

        // append the new post-gain K/V rows to each sequence's cache —
        // bit-for-bit the rows the whole-batch pass computes, by the
        // per-row GEMM contract (Mx-paged caches quantize here; the
        // attention below then reads the quantized rows back, which is
        // what keeps incremental and whole-prefix decode identical
        // under any one codec)
        {
            let mut r0 = 0usize;
            for (b, &l) in lens.iter().enumerate() {
                kvs[b].append_layer(
                    layer,
                    &ky[r0 * d..(r0 + l) * d],
                    &vv[r0 * d..(r0 + l) * d],
                )?;
                r0 += l;
            }
        }

        // causal attention over cache + new rows, full precision (paper
        // App. A); reductions run in ascending position order — the
        // exact op sequence of the whole-batch loop
        let mut o = vec![0.0f32; rows * d];
        let mut r0 = 0usize;
        for (b, &l) in lens.iter().enumerate() {
            let (kc, vc) = kvs[b].layer_rows(
                layer,
                &mut k_scratch,
                &mut v_scratch,
                &mut code_scratch,
            );
            for head in 0..nh {
                let c0 = head * hd;
                for i in 0..l {
                    let qi = (r0 + i) * d + c0;
                    let ctx_len = pasts[b] + i + 1;
                    let mut maxv = f32::NEG_INFINITY;
                    for j in 0..ctx_len {
                        let kj = j * d + c0;
                        let mut dot = 0.0f32;
                        for t in 0..hd {
                            dot += q[qi + t] * kc[kj + t];
                        }
                        let sc = dot * att_scale;
                        att[j] = sc;
                        if sc > maxv {
                            maxv = sc;
                        }
                    }
                    let mut denom = 0.0f32;
                    for a in att.iter_mut().take(ctx_len) {
                        let e = (*a - maxv).exp();
                        *a = e;
                        denom += e;
                    }
                    for a in att.iter_mut().take(ctx_len) {
                        *a /= denom;
                    }
                    let oi = (r0 + i) * d + c0;
                    for t in 0..hd {
                        let mut acc = 0.0f32;
                        for j in 0..ctx_len {
                            acc += att[j] * vc[j * d + c0 + t];
                        }
                        o[oi + t] = acc;
                    }
                }
            }
            r0 += l;
        }

        let proj = scaled(linear(layer, 3, &o, rows)?, g[3]);
        add_into(&mut x, &proj);

        let h2 = layer_norm(
            &x,
            &ctx.ln2_g[layer * d..(layer + 1) * d],
            &ctx.ln2_b[layer * d..(layer + 1) * d],
            d,
        );
        let mut mid = scaled(linear(layer, 4, &h2, rows)?, g[4]);
        for m in mid.iter_mut() {
            *m = gelu(*m);
        }
        let proj2 = scaled(linear(layer, 5, &mid, rows)?, g[5]);
        add_into(&mut x, &proj2);
    }
    for (kv, &l) in kvs.iter_mut().zip(lens) {
        kv.len += l;
    }

    // the model head is NOT quantized (paper App. A); LN + head are
    // per-row, so the last-row-only path is bit-identical to slicing
    // the all-rows result
    if last_only {
        let mut out = vec![0.0f32; batch * v];
        let mut r0 = 0usize;
        for (b, &l) in lens.iter().enumerate() {
            let r = r0 + l - 1;
            let xf = layer_norm(&x[r * d..(r + 1) * d], ctx.lnf_g, ctx.lnf_b, d);
            let row = matmul_t(&xf, ctx.head_t, 1, d, v);
            out[b * v..(b + 1) * v].copy_from_slice(&row);
            r0 += l;
        }
        return Ok(out);
    }
    let xf = layer_norm(&x, ctx.lnf_g, ctx.lnf_b, d);
    Ok(matmul_t(&xf, ctx.head_t, rows, d, v))
}

fn layer_norm(x: &[f32], g: &[f32], b: &[f32], d: usize) -> Vec<f32> {
    let rows = x.len() / d;
    let mut out = vec![0.0f32; x.len()];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut mu = 0.0f32;
        for &v in xr {
            mu += v;
        }
        mu /= d as f32;
        let mut var = 0.0f32;
        for &v in xr {
            let dv = v - mu;
            var += dv * dv;
        }
        var /= d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let or = &mut out[r * d..(r + 1) * d];
        for c in 0..d {
            or[c] = (xr[c] - mu) * inv * g[c] + b[c];
        }
    }
    out
}

/// tanh-approximation GELU (the `jax.nn.gelu` default).
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

fn scaled(mut y: Vec<f32>, gain: f32) -> Vec<f32> {
    if gain != 1.0 {
        for v in y.iter_mut() {
            *v *= gain;
        }
    }
    y
}

fn add_into(x: &mut [f32], y: &[f32]) {
    for (a, b) in x.iter_mut().zip(y) {
        *a += *b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Pcg64;
    use crate::serve::cache::OperandCache;

    fn tiny_dims() -> ModelDims {
        ModelDims {
            vocab: 32,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            d_ff: 64,
            seq_len: 8,
        }
    }

    fn tokens(rng: &mut Pcg64, dims: &ModelDims, rows: usize) -> Vec<i32> {
        (0..rows)
            .map(|_| (rng.next_u64() % dims.vocab as u64) as i32)
            .collect()
    }

    #[test]
    fn packed_forward_matches_reference_smoke() {
        let dims = tiny_dims();
        let params = Params::init_surrogate(&dims, 11);
        let cache = OperandCache::new(32);
        let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap());
        let model =
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap();
        assert_eq!(model.path_summary().packed, 2 * 6);
        assert!(model.packed_weight_bytes() > 0);
        let mut rng = Pcg64::new(12);
        let toks = tokens(&mut rng, &dims, 2 * dims.seq_len);
        let got = model.forward(&toks, 2, dims.seq_len).unwrap();
        let want = reference_forward(
            &params,
            &dims,
            &qcfg,
            8,
            &toks,
            2,
            dims.seq_len,
        )
        .unwrap();
        assert_eq!(got.len(), 2 * dims.seq_len * dims.vocab);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "logit {i}: {a} vs {b}");
        }
    }

    #[test]
    fn baseline_config_bypasses_quantization() {
        let dims = tiny_dims();
        let params = Params::init_surrogate(&dims, 13);
        let cache = OperandCache::new(8);
        let qcfg = PerLayerQConfig::uniform(QConfig::baseline());
        let model =
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap();
        let s = model.path_summary();
        assert_eq!((s.exact, s.packed, s.reference), (12, 0, 0));
        // no operands were packed for exact layers
        assert_eq!(cache.stats().misses, 0);
    }

    #[test]
    fn mixed_layers_take_their_own_paths() {
        let dims = tiny_dims();
        let params = Params::init_surrogate(&dims, 14);
        let cache = OperandCache::new(32);
        let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap())
            .with_override(
                1,
                QConfig::named("int4", "ue4m3", false).unwrap(),
            );
        let model =
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap();
        let s = model.path_summary();
        // layer 0: packed FP4; layer 1: INT4 -> reference
        assert_eq!((s.exact, s.packed, s.reference), (0, 6, 6));
        let mut rng = Pcg64::new(15);
        let toks = tokens(&mut rng, &dims, dims.seq_len);
        let got = model.forward(&toks, 1, dims.seq_len).unwrap();
        let want = reference_forward(
            &params,
            &dims,
            &qcfg,
            8,
            &toks,
            1,
            dims.seq_len,
        )
        .unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn sharded_forward_is_bit_identical_to_unsharded() {
        let dims = tiny_dims();
        let params = Params::init_surrogate(&dims, 21);
        let cache = OperandCache::new(64);
        let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap());
        let base =
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap();
        let mut rng = Pcg64::new(22);
        let toks = tokens(&mut rng, &dims, 2 * dims.seq_len);
        let want = base.forward(&toks, 2, dims.seq_len).unwrap();
        for shards in [2usize, 3, 7] {
            let model = PackedModel::build_sharded(
                &dims, &params, &qcfg, 8, &cache, shards,
            )
            .unwrap();
            assert_eq!(model.shards(), shards);
            // sharding never changes the path split or the wire bytes'
            // resident total
            assert_eq!(model.path_summary(), base.path_summary());
            let got = model.forward(&toks, 2, dims.seq_len).unwrap();
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "shards={shards} logit {i}: {a} vs {b}"
                );
            }
        }
        // a zero shard count is rejected, not clamped
        assert!(PackedModel::build_sharded(
            &dims, &params, &qcfg, 8, &cache, 0
        )
        .is_err());
    }

    #[test]
    fn rotated_packed_forward_matches_rotated_reference() {
        let dims = tiny_dims();
        let params = Params::init_surrogate(&dims, 31);
        let cache = OperandCache::new(64);
        let qcfg = PerLayerQConfig::uniform(
            QConfig::fp4("ue4m3").unwrap().with_rotate(true),
        );
        let model =
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap();
        assert_eq!(model.path_summary().packed, 12);
        let mut rng = Pcg64::new(32);
        let toks = tokens(&mut rng, &dims, 2 * dims.seq_len);
        let got = model.forward(&toks, 2, dims.seq_len).unwrap();
        let want = reference_forward(
            &params,
            &dims,
            &qcfg,
            8,
            &toks,
            2,
            dims.seq_len,
        )
        .unwrap();
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "logit {i}: {a} vs {b}");
        }
        // rotation changes the numbers vs the unrotated config
        let plain = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap());
        let unrot = PackedModel::build(&dims, &params, &plain, 8, &cache)
            .unwrap()
            .forward(&toks, 2, dims.seq_len)
            .unwrap();
        assert!(got.iter().zip(&unrot).any(|(a, b)| a.to_bits() != b.to_bits()));
    }

    #[test]
    fn rotated_sharded_forward_is_bit_identical_to_unsharded() {
        let dims = tiny_dims();
        let params = Params::init_surrogate(&dims, 33);
        let cache = OperandCache::new(64);
        let qcfg = PerLayerQConfig::uniform(
            QConfig::fp4("ue5m3").unwrap().with_rotate(true),
        );
        let base =
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap();
        let mut rng = Pcg64::new(34);
        let toks = tokens(&mut rng, &dims, dims.seq_len);
        let want = base.forward(&toks, 1, dims.seq_len).unwrap();
        for shards in [2usize, 3] {
            let got = PackedModel::build_sharded(
                &dims, &params, &qcfg, 8, &cache, shards,
            )
            .unwrap()
            .forward(&toks, 1, dims.seq_len)
            .unwrap();
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "shards={shards} logit {i}");
            }
        }
    }

    #[test]
    fn per_layer_block_size_override_flows_through() {
        let dims = tiny_dims();
        let params = Params::init_surrogate(&dims, 35);
        let cache = OperandCache::new(64);
        // layer 0 at bs8 (the global), layer 1 overridden to bs16
        let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap())
            .with_override(
                1,
                QConfig::fp4("ue4m3").unwrap().with_block_size(16),
            );
        let model =
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap();
        assert_eq!(model.path_summary().packed, 12);
        let mut rng = Pcg64::new(36);
        let toks = tokens(&mut rng, &dims, dims.seq_len);
        let got = model.forward(&toks, 1, dims.seq_len).unwrap();
        let want = reference_forward(
            &params,
            &dims,
            &qcfg,
            8,
            &toks,
            1,
            dims.seq_len,
        )
        .unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // and differs from the uniform-bs8 forward
        let uni = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap());
        let u = PackedModel::build(&dims, &params, &uni, 8, &cache)
            .unwrap()
            .forward(&toks, 1, dims.seq_len)
            .unwrap();
        assert!(got.iter().zip(&u).any(|(a, b)| a.to_bits() != b.to_bits()));
        // an override that does not divide the contraction dim is refused
        let bad = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap())
            .with_override(
                0,
                QConfig::fp4("ue4m3").unwrap().with_block_size(24),
            );
        assert!(PackedModel::build(&dims, &params, &bad, 8, &cache).is_err());
    }

    #[test]
    fn capture_matches_reference_inputs() {
        let dims = tiny_dims();
        let params = Params::init_surrogate(&dims, 37);
        let mut rng = Pcg64::new(38);
        let toks = tokens(&mut rng, &dims, 2 * dims.seq_len);
        let caps =
            capture_linear_inputs(&params, &dims, &toks, 2, dims.seq_len)
                .unwrap();
        assert_eq!(caps.len(), dims.n_layers * 6);
        let rows = 2 * dims.seq_len;
        for (i, c) in caps.iter().enumerate() {
            let which = i % 6;
            let (kd, _) = linear_dims(&dims, which);
            assert_eq!(c.len(), rows * kd, "linear {i}");
            assert!(c.iter().any(|v| *v != 0.0), "linear {i} all zero");
        }
        // deterministic: same tokens → same bits
        let again =
            capture_linear_inputs(&params, &dims, &toks, 2, dims.seq_len)
                .unwrap();
        for (a, b) in caps.iter().zip(&again) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn forward_validates_inputs() {
        let dims = tiny_dims();
        let params = Params::init_surrogate(&dims, 16);
        let cache = OperandCache::new(8);
        let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap());
        let model =
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap();
        // token out of range
        assert!(model.forward(&[99; 8], 1, 8).is_err());
        // wrong token count
        assert!(model.forward(&[0; 7], 1, 8).is_err());
        // seq too long
        assert!(model.forward(&[0; 16], 1, 16).is_err());
        // short sequences are fine
        assert!(model.forward(&[0; 4], 1, 4).is_ok());
        // misaligned block size refused at build
        assert!(
            PackedModel::build(&dims, &params, &qcfg, 24, &cache).is_err()
        );
    }
}
