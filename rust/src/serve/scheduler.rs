//! Continuous-batching generation scheduler.
//!
//! One [`Scheduler`] drives many concurrent generation requests through
//! a [`DecodeEngine`], admitting and evicting sequences **mid-flight**:
//! every [`Scheduler::step`] builds a single ragged spine call that
//! prefills newly admitted prompts *and* decodes one token for every
//! live sequence at once, then samples, then retires finished sequences
//! — the vLLM-style iteration-level scheduling loop, minus the GPU.
//!
//! # Sequence lifecycle
//!
//! ```text
//! waiting ──admit──▶ prefill ──▶ decoding ──stop──▶ finished
//!            ▲  (≤ max_prefill_per_step joins per step,          │
//!            │   ≤ max_prefill_tokens fresh prefix rows mixed    │
//!            │   into one ragged batch (chunked prefill),        │
//!            │   ≤ max_active sequences KV-resident,             │
//!            │   and — with a KvPool — only if the step's pages  │
//!            │   fit the byte budget)                            │
//!            └────────────── preempted ◀──evict-at-capacity──────┘
//! ```
//!
//! Stop conditions, checked after each sampled token: the token equals
//! `eos` (kept in the output), `max_new_tokens` reached, or the context
//! window is exhausted ([`FinishReason::ContextFull`] — the final token
//! is still returned; it just cannot be fed back).
//!
//! The scheduler is agnostic to tensor-parallel sharding: a model from
//! [`crate::serve::PackedModel::build_sharded`] fans each fused
//! prefill+decode spine call out across its shard pool and yields the
//! same token streams as `shards = 1` — including under paged-KvPool
//! eviction and requeue, which `rust/tests/shard.rs` pins against the
//! cache-free oracle.
//!
//! # Priority classes
//!
//! Every request carries a [`Priority`]: `Interactive` requests jump
//! the admission queue (within the preempted set first, then the
//! waiting set — FIFO *within* each class) and are the last candidates
//! for eviction (the victim is the youngest `Batch` sequence when one
//! exists, the youngest sequence otherwise). Priorities reorder *when*
//! work runs, never *what* it computes: the per-request determinism
//! contract below makes token streams invariant to admission order, so
//! each class keeps the exact streams it would see alone.
//!
//! # Chunked prefill
//!
//! [`SchedulerConfig::max_prefill_tokens`] bounds the fresh prefix rows
//! one ragged step may mix in across prefilling sequences — without it,
//! one context-length prompt joins the batch as a single giant prefill
//! and stalls every live stream's next token. A prefilling sequence
//! feeds `prefix[kv.len() .. kv.len() + chunk]` per step (the spine
//! appends after the cached positions, so chunking is just a smaller
//! append) and samples only on the step that completes its prefix;
//! decode feeds and that final completing token are exempt from the
//! budget, so a step that would emit a token is never blocked. Logits
//! at the sampled position are a pure function of the full prefix —
//! streams are **invariant to the cap** (`rust/tests/decode.rs` pins
//! this by sweeping it).
//!
//! # Memory-bounded scheduling
//!
//! When the engine carries a [`crate::serve::KvPool`]
//! ([`DecodeEngine::with_pool`]), every step **reserves** its page cost
//! up front with the pool's exact page arithmetic
//! ([`crate::serve::KvPool::bytes_for_rows`]): admission stops at the
//! first candidate whose prefill pages don't fit (admission blocks —
//! FIFO order within a priority class is preserved), and if the live
//! sequences' next decode step itself no longer fits, a victim is
//! evicted — its pages return to the pool and the request moves to
//! the head of a preempted queue ([`Scheduler::preempted`]) with its
//! sampler state and generated tokens intact. A preempted sequence
//! resumes by re-prefilling `prompt ++ generated` (chunked like any
//! prefill); under the Exact codec the full-prefix exactness contract
//! makes the resumed logits bit-identical to the uninterrupted ones
//! (and under an Mx codec identical under that same codec), so
//! **preemption never changes a token stream** — pinned by
//! `rust/tests/kvpool.rs`. The engine guarantees the budget fits one
//! full-context sequence, so evicting down to a single sequence always
//! makes progress. Reservations deliberately price every page as
//! private even on a prefix-sharing pool — dedup can only hand bytes
//! back ([`crate::serve::kvpool`] module docs).
//!
//! # Speculation mode
//!
//! [`Scheduler::new_speculative`] attaches a *draft* model — the same
//! weight source under a cheaper quant config (default FP4/UE5M3) —
//! and turns every decode-phase sequence's single-token step into a
//! verify window: the draft proposes up to `k` greedy tokens (one
//! batched ragged catch-up call plus single-token steps), and the
//! step's **one** target spine call verifies every window alongside
//! the usual prefill chunks (`last_only = false`, so all window rows'
//! logits return). Replay acceptance — each sequence's own sampler
//! re-picks every emitted token from the target's logits rows, which
//! the multi-token append contract makes bit-identical to
//! step-by-step decode — keeps every token stream exactly what the
//! base scheduler emits; rejected rows roll back off both caches via
//! [`SeqKv::truncate`]. Draft caches live in the shared
//! [`crate::serve::KvPool`] under their own codec bank
//! ([`crate::serve::KvPool::build_spec`]) and are the first thing
//! dropped under memory pressure (the sequence degrades to plain
//! decode — draft pages evict before any sequence does), so
//! speculation never weakens the progress guarantee. DESIGN.md §15.
//!
//! # Streaming and cancellation
//!
//! [`Scheduler::submit_streaming`] attaches an `mpsc` sink that
//! receives one [`StreamEvent::Token`] per sampled token as it is
//! emitted and a final [`StreamEvent::Done`] carrying the
//! [`DecodeResult`] (streamed results are delivered there, **not**
//! through [`Scheduler::take_finished`]). A dropped receiver — the
//! HTTP front-end's client-disconnect signal — cancels the sequence at
//! its next token: its pages return to the pool immediately and no
//! result is recorded. [`Scheduler::cancel`] does the same by request
//! id from any state (waiting, preempted, or active). Cancellation
//! cannot perturb surviving streams (per-request determinism again).
//!
//! # Determinism
//!
//! A request's token stream is a pure function of
//! `(weights, qconfig, prompt, sampling policy)`: step logits are
//! bit-identical to the full-prefix reference regardless of which
//! neighbors share the ragged batch (batching invariance + the decode
//! exactness contract), and each request samples from its **own**
//! seeded [`crate::dist::Pcg64`] stream. Admission order, `max_active`,
//! priorities, prefill chunking, and GEMM threading therefore cannot
//! change any stream — `rust/tests/decode.rs` pins this by permuting
//! all of them.

use std::collections::VecDeque;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::ensure;

use super::decode::{DecodeEngine, Sampler, Sampling, SeqKv};
use super::packed_model::PackedModel;
use super::spec::{accept_window, argmax};

/// Admission/eviction priority class (see module docs): priorities
/// reorder scheduling, never token streams.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Latency-sensitive: admitted first, evicted last.
    #[default]
    Interactive,
    /// Throughput traffic: yields admission slots and eviction victims
    /// to interactive work.
    Batch,
}

impl Priority {
    /// Stable lowercase name (JSON/CLI surface).
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }

    /// Inverse of [`Priority::as_str`].
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "interactive" => Some(Priority::Interactive),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }
}

/// One generation request.
#[derive(Debug, Clone)]
pub struct DecodeRequest {
    /// Caller-chosen id, echoed in the result (need not be unique, but
    /// results sort by it).
    pub id: u64,
    /// Prompt tokens (`1..=seq_len`).
    pub prompt: Vec<i32>,
    /// Generation budget (≥ 1).
    pub max_new_tokens: usize,
    /// Optional stop token (kept in the output when hit).
    pub eos: Option<i32>,
    pub sampling: Sampling,
    /// Admission/eviction class — cannot change the token stream.
    pub priority: Priority,
}

/// Why a sequence retired.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Sampled the `eos` token.
    Eos,
    /// Generated `max_new_tokens`.
    MaxTokens,
    /// Prompt + generated tokens filled the model's context window.
    ContextFull,
}

impl FinishReason {
    /// Stable lowercase name (JSON surface).
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::ContextFull => "context_full",
        }
    }
}

/// A finished request: its generated tokens plus per-token timing.
#[derive(Debug, Clone)]
pub struct DecodeResult {
    pub id: u64,
    pub prompt_len: usize,
    pub priority: Priority,
    /// Generated tokens, in order (includes the `eos` token if hit).
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// Submit → first admission into the active set — the pure
    /// queueing share of [`DecodeResult::ttft`] (SLO verdicts separate
    /// admission delay from decode latency).
    pub queue_wait: Duration,
    /// Submit → first generated token (includes queueing + prefill).
    pub ttft: Duration,
    /// Gaps between consecutive token emissions (`tokens.len() - 1`
    /// entries) — the inter-token latency samples.
    pub itl: Vec<Duration>,
}

/// Per-token delivery for [`Scheduler::submit_streaming`].
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// One sampled token, sent the step it is emitted.
    Token(i32),
    /// The request retired; carries the full result (streamed requests
    /// do not appear in [`Scheduler::take_finished`]).
    Done(DecodeResult),
}

/// Scheduling policy.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// KV-resident sequences decoded concurrently.
    pub max_active: usize,
    /// New prompts admitted (started prefilling) per step.
    pub max_prefill_per_step: usize,
    /// Fresh prefix rows one ragged step may mix in across prefilling
    /// sequences (chunked prefill — module docs). Decode feeds and the
    /// token that completes a prefix are exempt, so a step that would
    /// sample is never blocked. Streams are invariant to this cap.
    pub max_prefill_tokens: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_active: 8,
            max_prefill_per_step: 2,
            max_prefill_tokens: usize::MAX,
        }
    }
}

/// A queued request awaiting admission.
struct Waiting {
    req: DecodeRequest,
    submitted: Instant,
    sink: Option<mpsc::Sender<StreamEvent>>,
}

struct Active {
    req: DecodeRequest,
    submitted: Instant,
    /// First admission into the active set (survives preemption).
    admitted: Instant,
    sink: Option<mpsc::Sender<StreamEvent>>,
    kv: SeqKv,
    /// Speculation mode only: the draft model's cache for this
    /// sequence (pool draft bank when pooled). Dropped first under
    /// memory pressure — losing it only costs re-catch-up, never
    /// tokens — and on eviction.
    draft_kv: Option<SeqKv>,
    sampler: Sampler,
    /// Generated tokens; the last one is the next decode-step input
    /// (unless the sequence just finished).
    out: Vec<i32>,
    emitted: Vec<Instant>,
}

impl Active {
    /// The full prefix this sequence replays: `prompt ++ generated`.
    fn prefix_len(&self) -> usize {
        self.req.prompt.len() + self.out.len()
    }

    /// Cache rows the sequence still needs before its next sample —
    /// the **conservative** admission price (chunking may spread the
    /// rows over several steps, never exceed them).
    fn step_len(&self) -> usize {
        if self.kv.len() == 0 {
            self.prefix_len()
        } else {
            1
        }
    }

    /// Prefix token at absolute position `pos`.
    fn prefix_at(&self, pos: usize) -> i32 {
        if pos < self.req.prompt.len() {
            self.req.prompt[pos]
        } else {
            self.out[pos - self.req.prompt.len()]
        }
    }
}

/// The continuous-batching driver (module docs). Single-threaded by
/// design — the parallelism lives in the GEMM under the spine, and a
/// deterministic driver is what makes the stream-invariance tests
/// meaningful. (The HTTP front-end gives it a thread of its own and
/// feeds it over a channel — `super::http`.)
pub struct Scheduler {
    engine: DecodeEngine,
    cfg: SchedulerConfig,
    spec: Option<SpecState>,
    waiting: VecDeque<Waiting>,
    /// Evicted-at-capacity sequences, resumed before new admissions
    /// (front = most recently evicted = next to resume).
    preempted: VecDeque<Active>,
    active: Vec<Active>,
    finished: Vec<DecodeResult>,
    preemptions: u64,
    cancelled: u64,
    peak_kv_bytes: usize,
}

/// Speculation mode state ([`Scheduler::new_speculative`]).
struct SpecState {
    /// The draft model's engine, used purely for its forward helpers —
    /// draft caches come from the shared pool's draft bank, never from
    /// this engine's `new_kv`.
    draft: DecodeEngine,
    /// Speculation depth: draft proposals per sequence per step.
    k: usize,
    proposed: u64,
    accepted: u64,
}

impl Scheduler {
    pub fn new(engine: DecodeEngine, cfg: SchedulerConfig) -> Scheduler {
        Scheduler {
            engine,
            cfg: SchedulerConfig {
                max_active: cfg.max_active.max(1),
                max_prefill_per_step: cfg.max_prefill_per_step.max(1),
                max_prefill_tokens: cfg.max_prefill_tokens.max(1),
            },
            spec: None,
            waiting: VecDeque::new(),
            preempted: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            preemptions: 0,
            cancelled: 0,
            peak_kv_bytes: 0,
        }
    }

    /// A scheduler in **speculation mode** (module docs): each step, a
    /// `draft` model — the same weight source under a cheaper quant
    /// config — proposes up to `k` greedy tokens per decode-phase
    /// sequence, and the target engine verifies every window in the
    /// step's single ragged spine call. Replay acceptance keeps every
    /// token stream bit-identical to the non-speculative scheduler
    /// (and therefore to the cache-free oracle) — speculation, like
    /// priorities, reorders *when* work runs, never *what* it
    /// computes. On a pooled engine the pool must carry a draft codec
    /// bank ([`crate::serve::KvPool::build_spec`]); draft caches
    /// allocate from it under the shared byte budget, and under
    /// memory pressure draft pages are dropped (sequences degrade to
    /// plain decode) before any sequence is evicted.
    pub fn new_speculative(
        engine: DecodeEngine,
        draft: Arc<PackedModel>,
        k: usize,
        cfg: SchedulerConfig,
    ) -> crate::Result<Scheduler> {
        ensure!(k >= 1, "speculation depth k must be >= 1 (got {k})");
        ensure!(
            engine.model().dims() == draft.dims(),
            "draft and target models must share one shape: {:?} vs {:?}",
            engine.model().dims(),
            draft.dims()
        );
        if let Some(p) = engine.pool() {
            ensure!(
                p.has_draft_bank(),
                "speculative scheduling over a pool needs a draft codec \
                 bank (build it with KvPool::build_spec)"
            );
        }
        // validates the draft model's decode contract (per-tensor
        // activation scaling is as illegal for drafts as for targets)
        let draft = DecodeEngine::new(draft)?;
        let mut s = Scheduler::new(engine, cfg);
        s.spec = Some(SpecState { draft, k, proposed: 0, accepted: 0 });
        Ok(s)
    }

    /// Speculation counters `(proposed, accepted)` since construction;
    /// `None` when not in speculation mode.
    pub fn spec_stats(&self) -> Option<(u64, u64)> {
        self.spec.as_ref().map(|s| (s.proposed, s.accepted))
    }

    fn validate(&self, req: &DecodeRequest) -> crate::Result<()> {
        let dims = *self.engine.model().dims();
        ensure!(
            !req.prompt.is_empty() && req.prompt.len() <= dims.seq_len,
            "prompt length {} out of range 1..={}",
            req.prompt.len(),
            dims.seq_len
        );
        for &t in &req.prompt {
            ensure!(
                t >= 0 && (t as usize) < dims.vocab,
                "prompt token {t} out of vocab range 0..{}",
                dims.vocab
            );
        }
        ensure!(req.max_new_tokens >= 1, "max_new_tokens must be >= 1");
        // fail fast on a bad sampling policy, before admission
        Sampler::new(&req.sampling)?;
        Ok(())
    }

    /// Queue a request (validated against the model's limits).
    pub fn submit(&mut self, req: DecodeRequest) -> crate::Result<()> {
        self.validate(&req)?;
        self.waiting.push_back(Waiting {
            req,
            submitted: Instant::now(),
            sink: None,
        });
        Ok(())
    }

    /// Queue a request whose tokens stream to `sink` as they are
    /// emitted, ending with [`StreamEvent::Done`]. A dropped receiver
    /// cancels the request at its next token (module docs).
    pub fn submit_streaming(
        &mut self,
        req: DecodeRequest,
        sink: mpsc::Sender<StreamEvent>,
    ) -> crate::Result<()> {
        self.validate(&req)?;
        self.waiting.push_back(Waiting {
            req,
            submitted: Instant::now(),
            sink: Some(sink),
        });
        Ok(())
    }

    /// Drop every request with `id` — waiting, preempted, or active
    /// (mid-flight: its KV pages return to the pool immediately).
    /// Returns how many sequences were cancelled; no result is
    /// recorded for them. Surviving streams are unaffected
    /// (per-request determinism).
    pub fn cancel(&mut self, id: u64) -> usize {
        let before = self.waiting.len() + self.preempted.len();
        self.waiting.retain(|w| w.req.id != id);
        self.preempted.retain(|a| a.req.id != id);
        let mut n =
            before - (self.waiting.len() + self.preempted.len());
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].req.id == id {
                let mut a = self.active.remove(i);
                a.kv.reset();
                n += 1;
            } else {
                i += 1;
            }
        }
        self.cancelled += n as u64;
        n
    }

    /// Requests not yet admitted.
    pub fn pending(&self) -> usize {
        self.waiting.len()
    }

    /// Sequences evicted at pool capacity, awaiting resume.
    pub fn preempted(&self) -> usize {
        self.preempted.len()
    }

    /// KV-resident sequences.
    pub fn active(&self) -> usize {
        self.active.len()
    }

    /// Ids of the KV-resident sequences, admission order.
    pub fn active_ids(&self) -> Vec<u64> {
        self.active.iter().map(|a| a.req.id).collect()
    }

    /// The engine's KV pool, when it decodes through one.
    pub fn pool(&self) -> Option<&std::sync::Arc<crate::serve::KvPool>> {
        self.engine.pool()
    }

    /// Whether no work remains (waiting, preempted, or KV-resident).
    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty()
            && self.preempted.is_empty()
            && self.active.is_empty()
    }

    /// Total resident KV bytes across live sequences (allocated page
    /// bytes when the engine runs on a [`crate::serve::KvPool`]),
    /// including draft caches in speculation mode.
    pub fn kv_resident_bytes(&self) -> usize {
        self.active
            .iter()
            .map(|a| {
                a.kv.resident_bytes()
                    + a.draft_kv
                        .as_ref()
                        .map(|d| d.resident_bytes())
                        .unwrap_or(0)
            })
            .sum()
    }

    /// High-water mark of [`Scheduler::kv_resident_bytes`] observed
    /// after each step.
    pub fn peak_kv_resident_bytes(&self) -> usize {
        self.peak_kv_bytes
    }

    /// Evict-and-requeue events so far.
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Sequences cancelled so far ([`Scheduler::cancel`] or a dropped
    /// streaming receiver).
    pub fn cancellations(&self) -> u64 {
        self.cancelled
    }

    /// Take the results finished so far (sorted by request id;
    /// streamed requests deliver through their sink instead).
    pub fn take_finished(&mut self) -> Vec<DecodeResult> {
        let mut out = std::mem::take(&mut self.finished);
        out.sort_by_key(|r| r.id);
        out
    }

    /// Exact page bytes the next spine call over `active` allocates
    /// (0 without a pool — inline caches are unbounded). Conservative
    /// under chunked prefill: prices the whole remaining prefix.
    fn planned_step_bytes(&self) -> usize {
        let Some(pool) = self.engine.pool() else { return 0 };
        self.active
            .iter()
            .map(|a| pool.bytes_for_rows(a.kv.len(), a.step_len()))
            .sum()
    }

    /// Whether the live set's next step plus `extra` additional fresh
    /// prefill rows fits the pool budget (vacuously true without one).
    fn step_fits(&self, extra_prefill_rows: usize) -> bool {
        match self.engine.pool() {
            None => true,
            Some(pool) => {
                self.planned_step_bytes()
                    + pool.bytes_for_positions(extra_prefill_rows)
                    <= pool.free_bytes()
            }
        }
    }

    /// Next admission candidate in `preempted`: the oldest-evicted
    /// `Interactive` sequence, else the front.
    fn pick_preempted(&self) -> Option<usize> {
        if self.preempted.is_empty() {
            return None;
        }
        Some(
            self.preempted
                .iter()
                .position(|a| a.req.priority == Priority::Interactive)
                .unwrap_or(0),
        )
    }

    /// Next admission candidate in `waiting`: the oldest `Interactive`
    /// request, else the front (FIFO within a class).
    fn pick_waiting(&self) -> Option<usize> {
        if self.waiting.is_empty() {
            return None;
        }
        Some(
            self.waiting
                .iter()
                .position(|w| w.req.priority == Priority::Interactive)
                .unwrap_or(0),
        )
    }

    /// Eviction victim: the youngest `Batch` sequence when one exists,
    /// the youngest sequence otherwise.
    fn pick_victim(&self) -> usize {
        self.active
            .iter()
            .rposition(|a| a.req.priority == Priority::Batch)
            .unwrap_or(self.active.len() - 1)
    }

    /// Admit up to the per-step budget while KV slots are free and —
    /// with a pool — while the candidate's (conservative, full-prefix)
    /// pages fit on top of the live set's planned step. Preempted
    /// sequences resume before fresh admissions, and interactive
    /// candidates go before batch ones; admission blocks at the first
    /// candidate that doesn't fit, preserving FIFO order within each
    /// priority class. (Speculation overhead is deliberately not
    /// priced here — the speculative step degrades itself to plain
    /// decode under pressure, so base pricing is the floor it can
    /// always reach.)
    fn admit_new(&mut self) -> crate::Result<()> {
        let mut admitted = 0usize;
        while self.active.len() < self.cfg.max_active
            && admitted < self.cfg.max_prefill_per_step
        {
            if let Some(idx) = self.pick_preempted() {
                if !self.step_fits(self.preempted[idx].step_len()) {
                    break;
                }
                let a = self.preempted.remove(idx).unwrap();
                self.active.push(a);
                admitted += 1;
                continue;
            }
            let Some(idx) = self.pick_waiting() else { break };
            if !self.step_fits(self.waiting[idx].req.prompt.len()) {
                break;
            }
            let w = self.waiting.remove(idx).unwrap();
            let sampler = Sampler::new(&w.req.sampling)?;
            self.active.push(Active {
                req: w.req,
                submitted: w.submitted,
                admitted: Instant::now(),
                sink: w.sink,
                kv: self.engine.new_kv(),
                draft_kv: None,
                sampler,
                out: Vec::new(),
                emitted: Vec::new(),
            });
            admitted += 1;
        }
        Ok(())
    }

    /// Run one scheduling iteration: admit (within KV slots *and* the
    /// pool's page budget; interactive first), evict-and-requeue if the
    /// live set outgrew the pool, one ragged forward (chunked prefill +
    /// decode fused), sample, stream, retire. Returns the progress made
    /// as cache rows appended (every sampled token appends its row) —
    /// 0 means nothing could run: either fully idle, or every admission
    /// is blocked on pool pages held *outside* this scheduler (check
    /// [`Scheduler::is_idle`] to tell the two apart; [`Scheduler::run`]
    /// errors on the latter instead of spinning).
    pub fn step(&mut self) -> crate::Result<usize> {
        if self.spec.is_some() {
            return self.step_spec();
        }
        self.admit_new()?;
        if self.active.is_empty() {
            return Ok(0);
        }

        // at capacity the live set itself may no longer fit (decode
        // growth crossing page boundaries): evict a victim — free its
        // pages, requeue it with sampler + tokens intact — until the
        // step fits. The engine's budget invariant (one full sequence
        // always fits) bounds this at one survivor.
        while !self.step_fits(0) {
            // the engine's budget invariant guarantees one sequence
            // *alone* always fits, so reaching zero evictable neighbors
            // means the shortfall is external: the process-wide pool's
            // pages are held by sequences outside this scheduler
            ensure!(
                self.active.len() > 1,
                "scheduler blocked: the KV pool cannot fit the last live \
                 sequence's next step — its pages are held outside this \
                 scheduler (free them or raise the budget)"
            );
            let mut victim = self.active.remove(self.pick_victim());
            victim.kv.reset();
            victim.draft_kv = None;
            self.preempted.push_front(victim);
            self.preemptions += 1;
        }

        // one ragged spine call. Each sequence feeds the next slice of
        // its `prompt ++ generated` prefix: everything that remains
        // when the prefill-token budget allows (a decode step is the
        // `remaining == 1` case and is budget-exempt), a partial chunk
        // or nothing otherwise — sequences with no chunk this step sit
        // the batch out.
        let mut prefill_left = self.cfg.max_prefill_tokens;
        let mut tokens = Vec::new();
        let mut lens = Vec::with_capacity(self.active.len());
        let mut in_batch = Vec::with_capacity(self.active.len());
        for a in &self.active {
            let have = a.kv.len();
            let remaining = a.prefix_len() - have;
            debug_assert!(remaining >= 1);
            let chunk = if remaining == 1 {
                1
            } else {
                let c = remaining.min(prefill_left);
                prefill_left -= c;
                c
            };
            in_batch.push(chunk > 0);
            if chunk == 0 {
                continue;
            }
            for pos in have..have + chunk {
                tokens.push(a.prefix_at(pos));
            }
            lens.push(chunk);
        }
        let mut kvs: Vec<SeqKv> = self
            .active
            .iter_mut()
            .zip(&in_batch)
            .filter(|(_, &ib)| ib)
            .map(|(a, _)| std::mem::take(&mut a.kv))
            .collect();
        let appended = tokens.len();
        let logits = match self.engine.step_ragged(&tokens, &lens, &mut kvs) {
            Ok(logits) => {
                let holders = self
                    .active
                    .iter_mut()
                    .zip(&in_batch)
                    .filter(|(_, &ib)| ib)
                    .map(|(a, _)| a);
                for (a, kv) in holders.zip(kvs) {
                    a.kv = kv;
                }
                logits
            }
            Err(e) => {
                // a failed forward may leave partial K/V rows in the
                // caches (forward_ragged's contract) — they are
                // unusable, so the in-flight sequences are dropped
                // rather than resumed against corrupt state. submit()
                // validation makes this unreachable in practice.
                self.active.clear();
                return Err(e);
            }
        };
        let now = Instant::now();
        self.peak_kv_bytes = self.peak_kv_bytes.max(self.kv_resident_bytes());
        let vocab = self.engine.model().dims().vocab;
        let seq_cap = self.engine.model().dims().seq_len;

        // sample one token per prefix-complete sequence (mid-prefill
        // chunks consumed a logits row but have nothing to sample),
        // stream it, then retire finished sequences and cancel ones
        // whose stream receiver hung up
        let mut i = 0usize;
        let mut b = 0usize;
        for ib in in_batch {
            if !ib {
                i += 1;
                continue;
            }
            let a = &mut self.active[i];
            if a.kv.len() < a.prefix_len() {
                // chunked prefill still in flight
                b += 1;
                i += 1;
                continue;
            }
            let tok = a.sampler.pick(&logits[b * vocab..(b + 1) * vocab]);
            b += 1;
            a.out.push(tok);
            a.emitted.push(now);
            let hung_up = a
                .sink
                .as_ref()
                .is_some_and(|s| s.send(StreamEvent::Token(tok)).is_err());
            if hung_up {
                // receiver dropped (client disconnect): cancel
                // mid-flight, pages back to the pool, no result
                let mut dead = self.active.remove(i);
                dead.kv.reset();
                self.cancelled += 1;
                continue;
            }
            let a = &mut self.active[i];
            let finish = if a.req.eos == Some(tok) {
                Some(FinishReason::Eos)
            } else if a.out.len() >= a.req.max_new_tokens {
                Some(FinishReason::MaxTokens)
            } else if a.kv.len() >= seq_cap {
                // the sampled token has no position left to occupy
                Some(FinishReason::ContextFull)
            } else {
                None
            };
            match finish {
                Some(f) => {
                    let mut done = self.active.remove(i);
                    let sink = done.sink.take();
                    let result = finalize(done, f);
                    match sink {
                        // the tokens already streamed; a hung-up
                        // receiver at Done needs no bookkeeping
                        Some(s) => {
                            let _ = s.send(StreamEvent::Done(result));
                        }
                        None => self.finished.push(result),
                    }
                }
                None => i += 1,
            }
        }
        Ok(appended)
    }

    /// One speculative scheduling iteration
    /// ([`Scheduler::new_speculative`]): admit exactly as the base
    /// step; plan per-sequence feeds — prefill chunks unchanged,
    /// decode-phase sequences get a draft window of up to `k`
    /// proposals; price the plan against the pool, **degrading
    /// windows to plain decode youngest-first and dropping their
    /// draft pages** before evicting any sequence; run the batched
    /// draft phase (one ragged catch-up call + single-token steps);
    /// verify everything in ONE target ragged spine call
    /// (`last_only = false` — every window row's logits come back);
    /// replay-accept per sequence with its own sampler; stream and
    /// retire as the base step does; and roll rejected rows off both
    /// caches with [`SeqKv::truncate`]. Token streams are
    /// bit-identical to the base scheduler's (module docs).
    fn step_spec(&mut self) -> crate::Result<usize> {
        self.admit_new()?;
        if self.active.is_empty() {
            return Ok(0);
        }
        let k_max = self.spec.as_ref().expect("spec mode").k;
        let dims = *self.engine.model().dims();
        let seq_cap = dims.seq_len;
        let vocab = dims.vocab;

        /// Per-sequence feed plan for this step.
        #[derive(Clone, Copy)]
        struct Plan {
            /// Target rows fed (0 = sits this batch out).
            chunk: usize,
            /// Draft proposals verified along with the feed.
            kb: usize,
        }
        // per-sequence speculation cap, degraded under pool pressure;
        // the plan is recomputed after every degrade/evict because the
        // prefill-token budget redistributes
        let mut kcap: Vec<usize> =
            self.active.iter().map(|_| k_max).collect();
        let plans: Vec<Plan> = loop {
            let mut prefill_left = self.cfg.max_prefill_tokens;
            let mut plans = Vec::with_capacity(self.active.len());
            for (a, &kc) in self.active.iter().zip(&kcap) {
                let have = a.kv.len();
                let remaining = a.prefix_len() - have;
                debug_assert!(remaining >= 1);
                if remaining == 1 {
                    // decode phase: the window appends kb + 1 rows
                    // (context room) and emits at most kb + 1 tokens
                    // (generation budget) — cap it so neither is ever
                    // exceeded mid-window
                    let kb = kc
                        .min((seq_cap - have).saturating_sub(1))
                        .min(
                            (a.req.max_new_tokens - a.out.len())
                                .saturating_sub(1),
                        );
                    plans.push(Plan { chunk: 1, kb });
                } else {
                    let c = remaining.min(prefill_left);
                    prefill_left -= c;
                    plans.push(Plan { chunk: c, kb: 0 });
                }
            }
            // price the plan: target verify rows plus draft catch-up +
            // proposal rows, both drawn from the one shared budget
            let fits = match self.engine.pool() {
                None => true,
                Some(pool) => {
                    let mut total = 0usize;
                    for (a, p) in self.active.iter().zip(&plans) {
                        total += pool
                            .bytes_for_rows(a.kv.len(), p.chunk + p.kb);
                        if p.kb > 0 {
                            let dlen = a
                                .draft_kv
                                .as_ref()
                                .map(|d| d.len())
                                .unwrap_or(0);
                            let dnew = a.prefix_len() - dlen + p.kb - 1;
                            total +=
                                pool.draft_bytes_for_rows(dlen, dnew);
                        }
                    }
                    total <= pool.free_bytes()
                }
            };
            if fits {
                break plans;
            }
            // draft pages evict first: degrade the youngest sequence
            // still speculating (or still holding a draft cache) to
            // plain decode — losing a draft cache costs catch-up
            // compute, never tokens — before any sequence eviction
            if let Some(i) = (0..kcap.len()).rev().find(|&i| {
                kcap[i] > 0 || self.active[i].draft_kv.is_some()
            }) {
                kcap[i] = 0;
                self.active[i].draft_kv = None;
                continue;
            }
            // every window is already plain decode: same shortfall
            // handling as the base step
            ensure!(
                self.active.len() > 1,
                "scheduler blocked: the KV pool cannot fit the last live \
                 sequence's next step — its pages are held outside this \
                 scheduler (free them or raise the budget)"
            );
            let vi = self.pick_victim();
            kcap.remove(vi);
            let mut victim = self.active.remove(vi);
            victim.kv.reset();
            victim.draft_kv = None;
            self.preempted.push_front(victim);
            self.preemptions += 1;
        };

        // --- draft phase: one ragged catch-up call over every token
        // the draft caches have not seen, then single-token steps
        // until each window is full. Proposals are greedy argmax —
        // seed-free, so they cannot perturb any request's RNG.
        let mut drafts: Vec<Vec<i32>> =
            vec![Vec::new(); self.active.len()];
        if plans.iter().any(|p| p.kb > 0) {
            let pool = self.engine.pool().cloned();
            let draft_model =
                self.spec.as_ref().expect("spec mode").draft.model().clone();
            let mut cur_gi: Vec<usize> = Vec::new();
            let mut cur_kv: Vec<SeqKv> = Vec::new();
            let mut tokens = Vec::new();
            let mut lens = Vec::new();
            for (i, p) in plans.iter().enumerate() {
                if p.kb == 0 {
                    continue;
                }
                let a = &mut self.active[i];
                let dkv = match a.draft_kv.take() {
                    Some(d) => d,
                    None => match &pool {
                        Some(pl) => pl.draft_seq()?,
                        None => draft_model.new_kv(),
                    },
                };
                for pos in dkv.len()..a.prefix_len() {
                    tokens.push(a.prefix_at(pos));
                }
                lens.push(a.prefix_len() - dkv.len());
                cur_gi.push(i);
                cur_kv.push(dkv);
            }
            let draft = &self.spec.as_ref().expect("spec mode").draft;
            let mut dl =
                match draft.step_ragged(&tokens, &lens, &mut cur_kv) {
                    Ok(l) => l,
                    Err(e) => {
                        // as in the base step: a failed forward may
                        // leave partial rows — drop in-flight state
                        self.active.clear();
                        return Err(e);
                    }
                };
            loop {
                let mut keep_gi = Vec::new();
                let mut keep_kv = Vec::new();
                let mut toks = Vec::new();
                for (r, (gi, kv)) in
                    cur_gi.drain(..).zip(cur_kv.drain(..)).enumerate()
                {
                    let d = argmax(&dl[r * vocab..(r + 1) * vocab]);
                    drafts[gi].push(d);
                    if drafts[gi].len() < plans[gi].kb {
                        toks.push(d);
                        keep_gi.push(gi);
                        keep_kv.push(kv);
                    } else {
                        self.active[gi].draft_kv = Some(kv);
                    }
                }
                if keep_gi.is_empty() {
                    break;
                }
                dl = match draft.step(&toks, &mut keep_kv) {
                    Ok(l) => l,
                    Err(e) => {
                        self.active.clear();
                        return Err(e);
                    }
                };
                cur_gi = keep_gi;
                cur_kv = keep_kv;
            }
        }

        // --- one target ragged spine call verifies everything:
        // prefill chunks feed as usual; each decode window feeds its
        // pending token plus all proposals. last_only = false returns
        // every fed row's logits — each window row is bit-identical
        // to the step-by-step logits at that position (the multi-token
        // append contract), which is what makes replay acceptance an
        // identity on token streams.
        let mut tokens = Vec::new();
        let mut lens = Vec::new();
        let mut in_batch = Vec::with_capacity(self.active.len());
        for (i, (a, p)) in self.active.iter().zip(&plans).enumerate() {
            in_batch.push(p.chunk > 0);
            if p.chunk == 0 {
                continue;
            }
            let have = a.kv.len();
            if p.kb == 0 {
                for pos in have..have + p.chunk {
                    tokens.push(a.prefix_at(pos));
                }
                lens.push(p.chunk);
            } else {
                debug_assert_eq!(drafts[i].len(), p.kb);
                tokens.push(a.prefix_at(a.prefix_len() - 1));
                tokens.extend_from_slice(&drafts[i]);
                lens.push(1 + p.kb);
            }
        }
        let mut kvs: Vec<SeqKv> = self
            .active
            .iter_mut()
            .zip(&in_batch)
            .filter(|(_, &ib)| ib)
            .map(|(a, _)| std::mem::take(&mut a.kv))
            .collect();
        let appended = tokens.len();
        let logits = match self
            .engine
            .model()
            .forward_ragged(&tokens, &lens, &mut kvs, false)
        {
            Ok(logits) => {
                let holders = self
                    .active
                    .iter_mut()
                    .zip(&in_batch)
                    .filter(|(_, &ib)| ib)
                    .map(|(a, _)| a);
                for (a, kv) in holders.zip(kvs) {
                    a.kv = kv;
                }
                logits
            }
            Err(e) => {
                self.active.clear();
                return Err(e);
            }
        };
        let now = Instant::now();
        self.peak_kv_bytes =
            self.peak_kv_bytes.max(self.kv_resident_bytes());

        // --- replay acceptance + retire, mirroring the base step's
        // emission mechanics (out/emitted/sink ordering, hang-up
        // cancellation, finish precedence eos > max_tokens > context)
        let mut round_proposed = 0u64;
        let mut round_accepted = 0u64;
        let mut i = 0usize; // active index (shifts on removal)
        let mut row = 0usize; // logits row offset
        let mut bpos = 0usize; // ragged batch position
        for (pi, ib) in in_batch.iter().enumerate() {
            if !*ib {
                i += 1;
                continue;
            }
            let span = lens[bpos];
            bpos += 1;
            let rows = &logits[row * vocab..(row + span) * vocab];
            row += span;
            let p = plans[pi];
            let a = &mut self.active[i];
            if a.kv.len() < a.prefix_len() {
                // chunked prefill still in flight: rows consumed,
                // nothing to sample yet
                i += 1;
                continue;
            }
            // prefix length before this step's emissions
            let base_len = a.prefix_len();
            // emission rows: the window's kb + 1 tail rows (for a
            // completing prefill chunk, exactly its last row)
            let erows = &rows[(span - 1 - p.kb) * vocab..];
            let window = &drafts[pi][..p.kb];
            round_proposed += p.kb as u64;
            let max_emit = (a.req.max_new_tokens - a.out.len())
                .min(seq_cap + 1 - base_len);
            let (emitted, accepted) = accept_window(
                &mut a.sampler,
                erows,
                vocab,
                window,
                a.req.eos,
                max_emit,
            );
            round_accepted += accepted as u64;
            debug_assert!(!emitted.is_empty());
            let mut hung_up = false;
            for &tok in &emitted {
                a.out.push(tok);
                a.emitted.push(now);
                hung_up = a.sink.as_ref().is_some_and(|s| {
                    s.send(StreamEvent::Token(tok)).is_err()
                });
                if hung_up {
                    break;
                }
            }
            if hung_up {
                // receiver dropped (client disconnect): cancel
                // mid-flight, pages back to the pool, no result
                let mut dead = self.active.remove(i);
                dead.kv.reset();
                dead.draft_kv = None;
                self.cancelled += 1;
                continue;
            }
            let a = &mut self.active[i];
            let last = *emitted.last().expect("window emits >= 1");
            let finish = if a.req.eos == Some(last) {
                Some(FinishReason::Eos)
            } else if a.out.len() >= a.req.max_new_tokens {
                Some(FinishReason::MaxTokens)
            } else if base_len - 1 + emitted.len() >= seq_cap {
                // the last emitted token has no position left to occupy
                Some(FinishReason::ContextFull)
            } else {
                None
            };
            match finish {
                Some(f) => {
                    let mut done = self.active.remove(i);
                    done.draft_kv = None;
                    let sink = done.sink.take();
                    let result = finalize(done, f);
                    match sink {
                        Some(s) => {
                            let _ = s.send(StreamEvent::Done(result));
                        }
                        None => self.finished.push(result),
                    }
                }
                None => {
                    // roll rejected window rows off both caches: the
                    // valid cached prefix is everything but the new
                    // pending token
                    let keep = a.prefix_len() - 1;
                    let trunc = a.kv.truncate(keep).and_then(|_| {
                        match a.draft_kv.as_mut() {
                            Some(d) => d.truncate(keep),
                            None => Ok(()),
                        }
                    });
                    if let Err(e) = trunc {
                        self.active.clear();
                        return Err(e);
                    }
                    i += 1;
                }
            }
        }
        let spec = self.spec.as_mut().expect("spec mode");
        spec.proposed += round_proposed;
        spec.accepted += round_accepted;
        Ok(appended)
    }

    /// Drive [`Scheduler::step`] until every submitted request has
    /// finished; returns all results sorted by request id (streamed
    /// requests deliver through their sinks instead).
    ///
    /// Errors instead of spinning if the scheduler can make no progress
    /// — possible only when the KV pool's pages are held by sequences
    /// *outside* this scheduler (the pool is process-wide), since the
    /// engine's budget invariant guarantees this scheduler's own
    /// sequences alone can always advance.
    pub fn run(&mut self) -> crate::Result<Vec<DecodeResult>> {
        while !self.is_idle() {
            let progressed = self.step()?;
            ensure!(
                progressed > 0 || self.is_idle(),
                "scheduler blocked: the KV pool has no room for the next \
                 request's prefill and no live sequence to evict — pages \
                 are held outside this scheduler (free them or raise the \
                 budget)"
            );
        }
        Ok(self.take_finished())
    }
}

fn finalize(a: Active, finish: FinishReason) -> DecodeResult {
    let ttft = a
        .emitted
        .first()
        .map(|t| t.duration_since(a.submitted))
        .unwrap_or_default();
    let itl = a
        .emitted
        .windows(2)
        .map(|w| w[1].duration_since(w[0]))
        .collect();
    DecodeResult {
        id: a.req.id,
        prompt_len: a.req.prompt.len(),
        priority: a.req.priority,
        tokens: a.out,
        finish,
        queue_wait: a.admitted.duration_since(a.submitted),
        ttft,
        itl,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::Params;
    use crate::runtime::artifacts::ModelDims;
    use crate::runtime::qconfig::{PerLayerQConfig, QConfig};
    use crate::serve::cache::OperandCache;
    use crate::serve::packed_model::PackedModel;
    use std::sync::Arc;

    fn engine() -> DecodeEngine {
        let dims = ModelDims {
            vocab: 32,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            d_ff: 64,
            seq_len: 8,
        };
        let params = Params::init_surrogate(&dims, 33);
        let cache = OperandCache::new(32);
        let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap());
        let model = Arc::new(
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap(),
        );
        DecodeEngine::new(model).unwrap()
    }

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> DecodeRequest {
        DecodeRequest {
            id,
            prompt,
            max_new_tokens: max_new,
            eos: None,
            sampling: Sampling::Greedy,
            priority: Priority::Interactive,
        }
    }

    #[test]
    fn drains_more_requests_than_slots() {
        let mut s = Scheduler::new(
            engine(),
            SchedulerConfig {
                max_active: 2,
                max_prefill_per_step: 1,
                ..SchedulerConfig::default()
            },
        );
        for id in 0..5 {
            s.submit(req(id, vec![1, 2, 3], 3)).unwrap();
        }
        assert_eq!(s.pending(), 5);
        let results = s.run().unwrap();
        assert_eq!(results.len(), 5);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), 3);
            assert_eq!(r.finish, FinishReason::MaxTokens);
            assert_eq!(r.itl.len(), 2);
            assert!(r.queue_wait <= r.ttft, "admission precedes tokens");
        }
        assert_eq!((s.pending(), s.active()), (0, 0));
        assert_eq!(s.kv_resident_bytes(), 0);
    }

    #[test]
    fn context_full_stops_generation() {
        let mut s = Scheduler::new(engine(), SchedulerConfig::default());
        // prompt fills 7 of 8 positions: token 1 lands the cache at 8
        // after the feed-back step, so exactly 2 tokens fit
        s.submit(req(9, vec![0; 7], 100)).unwrap();
        let r = &s.run().unwrap()[0];
        assert_eq!(r.tokens.len(), 2);
        assert_eq!(r.finish, FinishReason::ContextFull);
        // a full-window prompt still yields exactly one token
        s.submit(req(10, vec![0; 8], 100)).unwrap();
        let r = &s.run().unwrap()[0];
        assert_eq!(r.tokens.len(), 1);
        assert_eq!(r.finish, FinishReason::ContextFull);
    }

    #[test]
    fn submit_validates_requests() {
        let mut s = Scheduler::new(engine(), SchedulerConfig::default());
        assert!(s.submit(req(0, vec![], 3)).is_err());
        assert!(s.submit(req(0, vec![0; 9], 3)).is_err());
        assert!(s.submit(req(0, vec![99], 3)).is_err());
        assert!(s.submit(req(0, vec![1], 0)).is_err());
        let bad_temp = DecodeRequest {
            sampling: Sampling::Temperature { temp: -1.0, seed: 0 },
            ..req(0, vec![1], 3)
        };
        assert!(s.submit(bad_temp).is_err());
        assert_eq!(s.pending(), 0);
    }

    #[test]
    fn interactive_requests_jump_the_admission_queue() {
        let mut s = Scheduler::new(
            engine(),
            SchedulerConfig {
                max_active: 1,
                max_prefill_per_step: 1,
                ..SchedulerConfig::default()
            },
        );
        let batch = DecodeRequest {
            priority: Priority::Batch,
            ..req(0, vec![1, 2], 2)
        };
        s.submit(batch.clone()).unwrap();
        s.submit(DecodeRequest { id: 1, ..batch.clone() }).unwrap();
        s.submit(req(2, vec![1, 2], 2)).unwrap();
        // one slot: the interactive request (id 2) must run first even
        // though two batch requests queued ahead of it
        s.step().unwrap();
        assert_eq!(s.active_ids(), vec![2]);
        let results = s.run().unwrap();
        assert_eq!(
            results.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2],
            "take_finished sorts by id regardless of completion order"
        );
        assert_eq!(results[2].priority, Priority::Interactive);
    }

    #[test]
    fn streams_are_invariant_to_the_prefill_token_cap() {
        // same request mix under max_prefill_tokens ∈ {1, 3, unlimited}:
        // chunking spreads prefix rows over steps but samples from the
        // same completed-prefix logits, so every stream is identical
        let run_with = |cap: usize| {
            let mut s = Scheduler::new(
                engine(),
                SchedulerConfig {
                    max_active: 4,
                    max_prefill_per_step: 2,
                    max_prefill_tokens: cap,
                },
            );
            for id in 0..4 {
                let prompt: Vec<i32> =
                    (0..5).map(|t| ((t + id) % 32) as i32).collect();
                s.submit(req(id, prompt, 3)).unwrap();
            }
            s.run()
                .unwrap()
                .into_iter()
                .map(|r| (r.id, r.tokens))
                .collect::<Vec<_>>()
        };
        let reference = run_with(usize::MAX);
        for cap in [1, 3] {
            assert_eq!(run_with(cap), reference, "cap {cap}");
        }
    }

    #[test]
    fn streaming_sink_receives_tokens_then_done() {
        let mut s = Scheduler::new(engine(), SchedulerConfig::default());
        let (tx, rx) = mpsc::channel();
        s.submit_streaming(req(7, vec![1, 2, 3], 3), tx).unwrap();
        // a plain request alongside keeps take_finished() exercised
        s.submit(req(8, vec![1, 2, 3], 3)).unwrap();
        let results = s.run().unwrap();
        assert_eq!(results.len(), 1, "streamed result not in finished");
        assert_eq!(results[0].id, 8);
        let events: Vec<StreamEvent> = rx.try_iter().collect();
        assert_eq!(events.len(), 4, "3 tokens + Done");
        let mut streamed = Vec::new();
        for e in &events[..3] {
            match e {
                StreamEvent::Token(t) => streamed.push(*t),
                StreamEvent::Done(_) => panic!("Done before last token"),
            }
        }
        match &events[3] {
            StreamEvent::Done(r) => {
                assert_eq!(r.id, 7);
                assert_eq!(r.tokens, streamed);
                // determinism: identical prompt+sampling ⇒ identical
                // stream, whether streamed or collected
                assert_eq!(r.tokens, results[0].tokens);
            }
            StreamEvent::Token(_) => panic!("expected Done last"),
        }
    }

    #[test]
    fn dropped_receiver_cancels_mid_flight() {
        let mut s = Scheduler::new(engine(), SchedulerConfig::default());
        let (tx, rx) = mpsc::channel();
        s.submit_streaming(req(1, vec![1, 2, 3], 100), tx).unwrap();
        s.submit(req(2, vec![1, 2, 3], 4)).unwrap();
        s.step().unwrap(); // both prefill + first token
        drop(rx);
        let results = s.run().unwrap();
        assert_eq!(s.cancellations(), 1);
        assert_eq!(results.len(), 1, "only the survivor finishes");
        assert_eq!(results[0].id, 2);
        assert!(s.is_idle());
    }

    fn spec_pair() -> (DecodeEngine, Arc<PackedModel>) {
        let dims = ModelDims {
            vocab: 32,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            d_ff: 64,
            seq_len: 16,
        };
        let params = Params::init_surrogate(&dims, 33);
        let cache = OperandCache::new(32);
        let target = Arc::new(
            PackedModel::build(
                &dims,
                &params,
                &PerLayerQConfig::uniform(QConfig::baseline()),
                8,
                &cache,
            )
            .unwrap(),
        );
        let draft = Arc::new(
            PackedModel::build(
                &dims,
                &params,
                &PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap()),
                8,
                &cache,
            )
            .unwrap(),
        );
        (DecodeEngine::new(target).unwrap(), draft)
    }

    fn spec_mix() -> Vec<DecodeRequest> {
        (0..4)
            .map(|id| {
                let prompt: Vec<i32> =
                    (0..4).map(|t| ((3 * t + id) % 32) as i32).collect();
                DecodeRequest {
                    id,
                    prompt,
                    max_new_tokens: 6,
                    eos: None,
                    sampling: if id % 2 == 0 {
                        Sampling::Greedy
                    } else {
                        Sampling::Temperature { temp: 0.8, seed: id }
                    },
                    priority: Priority::Interactive,
                }
            })
            .collect()
    }

    #[test]
    fn speculative_streams_match_the_base_scheduler() {
        let (base_engine, _) = spec_pair();
        let mut base =
            Scheduler::new(base_engine, SchedulerConfig::default());
        for r in spec_mix() {
            base.submit(r).unwrap();
        }
        let want: Vec<_> = base
            .run()
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.tokens, r.finish))
            .collect();
        for k in [1usize, 2, 4] {
            let (engine, draft) = spec_pair();
            let mut s = Scheduler::new_speculative(
                engine,
                draft,
                k,
                SchedulerConfig::default(),
            )
            .unwrap();
            for r in spec_mix() {
                s.submit(r).unwrap();
            }
            let got: Vec<_> = s
                .run()
                .unwrap()
                .into_iter()
                .map(|r| (r.id, r.tokens, r.finish))
                .collect();
            assert_eq!(got, want, "k={k}");
            let (proposed, accepted) = s.spec_stats().unwrap();
            assert!(proposed > 0, "k={k}: speculation never engaged");
            assert!(accepted <= proposed);
        }
    }

    #[test]
    fn speculative_scheduler_validates_its_models() {
        let (engine, draft) = spec_pair();
        assert!(Scheduler::new_speculative(
            engine,
            draft.clone(),
            0,
            SchedulerConfig::default()
        )
        .is_err());
        // mismatched shapes refused
        let dims = ModelDims {
            vocab: 32,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            d_ff: 64,
            seq_len: 8,
        };
        let params = Params::init_surrogate(&dims, 33);
        let cache = OperandCache::new(32);
        let small = Arc::new(
            PackedModel::build(
                &dims,
                &params,
                &PerLayerQConfig::uniform(QConfig::baseline()),
                8,
                &cache,
            )
            .unwrap(),
        );
        let (engine, _) = spec_pair();
        assert!(Scheduler::new_speculative(
            engine,
            small,
            2,
            SchedulerConfig::default()
        )
        .is_err());
    }

    #[test]
    fn cancel_by_id_covers_every_queue_state() {
        let mut s = Scheduler::new(
            engine(),
            SchedulerConfig {
                max_active: 1,
                max_prefill_per_step: 1,
                ..SchedulerConfig::default()
            },
        );
        for id in 0..3 {
            s.submit(req(id, vec![1, 2], 4)).unwrap();
        }
        s.step().unwrap(); // id 0 active; 1, 2 waiting
        assert_eq!(s.cancel(0), 1, "active");
        assert_eq!(s.cancel(2), 1, "waiting");
        assert_eq!(s.cancel(5), 0, "unknown id");
        assert_eq!(s.cancellations(), 2);
        let results = s.run().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].id, 1);
    }
}
