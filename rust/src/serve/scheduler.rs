//! Continuous-batching generation scheduler.
//!
//! One [`Scheduler`] drives many concurrent generation requests through
//! a [`DecodeEngine`], admitting and evicting sequences **mid-flight**:
//! every [`Scheduler::step`] builds a single ragged spine call that
//! prefills newly admitted prompts *and* decodes one token for every
//! live sequence at once, then samples, then retires finished sequences
//! — the vLLM-style iteration-level scheduling loop, minus the GPU.
//!
//! # Sequence lifecycle
//!
//! ```text
//! waiting ──admit──▶ prefill ──▶ decoding ──stop──▶ finished
//!            (≤ max_prefill_per_step joins per step,
//!             ≤ max_active sequences KV-resident)
//! ```
//!
//! Stop conditions, checked after each sampled token: the token equals
//! `eos` (kept in the output), `max_new_tokens` reached, or the context
//! window is exhausted ([`FinishReason::ContextFull`] — the final token
//! is still returned; it just cannot be fed back).
//!
//! # Determinism
//!
//! A request's token stream is a pure function of
//! `(weights, qconfig, prompt, sampling policy)`: step logits are
//! bit-identical to the full-prefix reference regardless of which
//! neighbors share the ragged batch (batching invariance + the decode
//! exactness contract), and each request samples from its **own**
//! seeded [`crate::dist::Pcg64`] stream. Admission order, `max_active`,
//! and GEMM threading therefore cannot change any stream —
//! `rust/tests/decode.rs` pins this by permuting all three.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::ensure;

use super::decode::{DecodeEngine, Sampler, Sampling, SeqKv};

/// One generation request.
#[derive(Debug, Clone)]
pub struct DecodeRequest {
    /// Caller-chosen id, echoed in the result (need not be unique, but
    /// results sort by it).
    pub id: u64,
    /// Prompt tokens (`1..=seq_len`).
    pub prompt: Vec<i32>,
    /// Generation budget (≥ 1).
    pub max_new_tokens: usize,
    /// Optional stop token (kept in the output when hit).
    pub eos: Option<i32>,
    pub sampling: Sampling,
}

/// Why a sequence retired.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Sampled the `eos` token.
    Eos,
    /// Generated `max_new_tokens`.
    MaxTokens,
    /// Prompt + generated tokens filled the model's context window.
    ContextFull,
}

/// A finished request: its generated tokens plus per-token timing.
#[derive(Debug, Clone)]
pub struct DecodeResult {
    pub id: u64,
    pub prompt_len: usize,
    /// Generated tokens, in order (includes the `eos` token if hit).
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// Submit → first generated token (includes queueing + prefill).
    pub ttft: Duration,
    /// Gaps between consecutive token emissions (`tokens.len() - 1`
    /// entries) — the inter-token latency samples.
    pub itl: Vec<Duration>,
}

/// Scheduling policy.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// KV-resident sequences decoded concurrently.
    pub max_active: usize,
    /// New prompts prefilled per step — bounds how much prefill work a
    /// single ragged batch mixes into the decode cadence (long prompts
    /// would otherwise stall every live stream's next token).
    pub max_prefill_per_step: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_active: 8, max_prefill_per_step: 2 }
    }
}

struct Active {
    req: DecodeRequest,
    submitted: Instant,
    kv: SeqKv,
    sampler: Sampler,
    /// Generated tokens; the last one is the next decode-step input
    /// (unless the sequence just finished).
    out: Vec<i32>,
    emitted: Vec<Instant>,
}

/// The continuous-batching driver (module docs). Single-threaded by
/// design — the parallelism lives in the GEMM under the spine, and a
/// deterministic driver is what makes the stream-invariance tests
/// meaningful.
pub struct Scheduler {
    engine: DecodeEngine,
    cfg: SchedulerConfig,
    waiting: VecDeque<(DecodeRequest, Instant)>,
    active: Vec<Active>,
    finished: Vec<DecodeResult>,
}

impl Scheduler {
    pub fn new(engine: DecodeEngine, cfg: SchedulerConfig) -> Scheduler {
        Scheduler {
            engine,
            cfg: SchedulerConfig {
                max_active: cfg.max_active.max(1),
                max_prefill_per_step: cfg.max_prefill_per_step.max(1),
            },
            waiting: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
        }
    }

    /// Queue a request (validated against the model's limits).
    pub fn submit(&mut self, req: DecodeRequest) -> crate::Result<()> {
        let dims = *self.engine.model().dims();
        ensure!(
            !req.prompt.is_empty() && req.prompt.len() <= dims.seq_len,
            "prompt length {} out of range 1..={}",
            req.prompt.len(),
            dims.seq_len
        );
        for &t in &req.prompt {
            ensure!(
                t >= 0 && (t as usize) < dims.vocab,
                "prompt token {t} out of vocab range 0..{}",
                dims.vocab
            );
        }
        ensure!(req.max_new_tokens >= 1, "max_new_tokens must be >= 1");
        // fail fast on a bad sampling policy, before admission
        Sampler::new(&req.sampling)?;
        self.waiting.push_back((req, Instant::now()));
        Ok(())
    }

    /// Requests not yet admitted.
    pub fn pending(&self) -> usize {
        self.waiting.len()
    }

    /// KV-resident sequences.
    pub fn active(&self) -> usize {
        self.active.len()
    }

    /// Total resident KV bytes across live sequences.
    pub fn kv_resident_bytes(&self) -> usize {
        self.active.iter().map(|a| a.kv.resident_bytes()).sum()
    }

    /// Take the results finished so far (sorted by request id).
    pub fn take_finished(&mut self) -> Vec<DecodeResult> {
        let mut out = std::mem::take(&mut self.finished);
        out.sort_by_key(|r| r.id);
        out
    }

    /// Run one scheduling iteration: admit, one ragged forward (prefill
    /// + decode fused), sample, retire. Returns the number of tokens
    /// generated (0 means fully idle).
    pub fn step(&mut self) -> crate::Result<usize> {
        // admit up to the prefill budget while KV slots are free
        let mut admitted = 0usize;
        while self.active.len() < self.cfg.max_active
            && admitted < self.cfg.max_prefill_per_step
        {
            let Some((req, submitted)) = self.waiting.pop_front() else {
                break;
            };
            let sampler = Sampler::new(&req.sampling)?;
            self.active.push(Active {
                req,
                submitted,
                kv: self.engine.new_kv(),
                sampler,
                out: Vec::new(),
                emitted: Vec::new(),
            });
            admitted += 1;
        }
        if self.active.is_empty() {
            return Ok(0);
        }

        // one ragged spine call: whole prompt for fresh sequences, one
        // token for live ones
        let mut tokens = Vec::new();
        let mut lens = Vec::with_capacity(self.active.len());
        for a in &self.active {
            if a.kv.len() == 0 {
                tokens.extend_from_slice(&a.req.prompt);
                lens.push(a.req.prompt.len());
            } else {
                tokens.push(*a.out.last().expect("decoding seq has a token"));
                lens.push(1);
            }
        }
        let mut kvs: Vec<SeqKv> = self
            .active
            .iter_mut()
            .map(|a| std::mem::take(&mut a.kv))
            .collect();
        let logits = match self.engine.step_ragged(&tokens, &lens, &mut kvs) {
            Ok(logits) => {
                for (a, kv) in self.active.iter_mut().zip(kvs) {
                    a.kv = kv;
                }
                logits
            }
            Err(e) => {
                // a failed forward may leave partial K/V rows in the
                // caches (forward_ragged's contract) — they are
                // unusable, so the in-flight sequences are dropped
                // rather than resumed against corrupt state. submit()
                // validation makes this unreachable in practice.
                self.active.clear();
                return Err(e);
            }
        };
        let now = Instant::now();
        let vocab = self.engine.model().dims().vocab;
        let seq_cap = self.engine.model().dims().seq_len;

        // sample one token per sequence, then retire finished ones
        let mut produced = 0usize;
        let mut b = 0usize;
        let mut i = 0usize;
        while i < self.active.len() {
            let a = &mut self.active[i];
            let tok = a.sampler.pick(&logits[b * vocab..(b + 1) * vocab]);
            a.out.push(tok);
            a.emitted.push(now);
            produced += 1;
            b += 1;
            let finish = if a.req.eos == Some(tok) {
                Some(FinishReason::Eos)
            } else if a.out.len() >= a.req.max_new_tokens {
                Some(FinishReason::MaxTokens)
            } else if a.kv.len() >= seq_cap {
                // the sampled token has no position left to occupy
                Some(FinishReason::ContextFull)
            } else {
                None
            };
            match finish {
                Some(f) => {
                    let done = self.active.remove(i);
                    self.finished.push(finalize(done, f));
                }
                None => i += 1,
            }
        }
        Ok(produced)
    }

    /// Drive [`Scheduler::step`] until every submitted request has
    /// finished; returns all results sorted by request id.
    pub fn run(&mut self) -> crate::Result<Vec<DecodeResult>> {
        while !self.waiting.is_empty() || !self.active.is_empty() {
            self.step()?;
        }
        Ok(self.take_finished())
    }
}

fn finalize(a: Active, finish: FinishReason) -> DecodeResult {
    let ttft = a
        .emitted
        .first()
        .map(|t| t.duration_since(a.submitted))
        .unwrap_or_default();
    let itl = a
        .emitted
        .windows(2)
        .map(|w| w[1].duration_since(w[0]))
        .collect();
    DecodeResult {
        id: a.req.id,
        prompt_len: a.req.prompt.len(),
        tokens: a.out,
        finish,
        ttft,
        itl,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::Params;
    use crate::runtime::artifacts::ModelDims;
    use crate::runtime::qconfig::{PerLayerQConfig, QConfig};
    use crate::serve::cache::OperandCache;
    use crate::serve::packed_model::PackedModel;
    use std::sync::Arc;

    fn engine() -> DecodeEngine {
        let dims = ModelDims {
            vocab: 32,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            d_ff: 64,
            seq_len: 8,
        };
        let params = Params::init_surrogate(&dims, 33);
        let cache = OperandCache::new(32);
        let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap());
        let model = Arc::new(
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap(),
        );
        DecodeEngine::new(model).unwrap()
    }

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> DecodeRequest {
        DecodeRequest {
            id,
            prompt,
            max_new_tokens: max_new,
            eos: None,
            sampling: Sampling::Greedy,
        }
    }

    #[test]
    fn drains_more_requests_than_slots() {
        let mut s = Scheduler::new(
            engine(),
            SchedulerConfig { max_active: 2, max_prefill_per_step: 1 },
        );
        for id in 0..5 {
            s.submit(req(id, vec![1, 2, 3], 3)).unwrap();
        }
        assert_eq!(s.pending(), 5);
        let results = s.run().unwrap();
        assert_eq!(results.len(), 5);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), 3);
            assert_eq!(r.finish, FinishReason::MaxTokens);
            assert_eq!(r.itl.len(), 2);
        }
        assert_eq!((s.pending(), s.active()), (0, 0));
        assert_eq!(s.kv_resident_bytes(), 0);
    }

    #[test]
    fn context_full_stops_generation() {
        let mut s = Scheduler::new(engine(), SchedulerConfig::default());
        // prompt fills 7 of 8 positions: token 1 lands the cache at 8
        // after the feed-back step, so exactly 2 tokens fit
        s.submit(req(9, vec![0; 7], 100)).unwrap();
        let r = &s.run().unwrap()[0];
        assert_eq!(r.tokens.len(), 2);
        assert_eq!(r.finish, FinishReason::ContextFull);
        // a full-window prompt still yields exactly one token
        s.submit(req(10, vec![0; 8], 100)).unwrap();
        let r = &s.run().unwrap()[0];
        assert_eq!(r.tokens.len(), 1);
        assert_eq!(r.finish, FinishReason::ContextFull);
    }

    #[test]
    fn submit_validates_requests() {
        let mut s = Scheduler::new(engine(), SchedulerConfig::default());
        assert!(s.submit(req(0, vec![], 3)).is_err());
        assert!(s.submit(req(0, vec![0; 9], 3)).is_err());
        assert!(s.submit(req(0, vec![99], 3)).is_err());
        assert!(s.submit(req(0, vec![1], 0)).is_err());
        let bad_temp = DecodeRequest {
            sampling: Sampling::Temperature { temp: -1.0, seed: 0 },
            ..req(0, vec![1], 3)
        };
        assert!(s.submit(bad_temp).is_err());
        assert_eq!(s.pending(), 0);
    }
}
