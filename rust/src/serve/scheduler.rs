//! Continuous-batching generation scheduler.
//!
//! One [`Scheduler`] drives many concurrent generation requests through
//! a [`DecodeEngine`], admitting and evicting sequences **mid-flight**:
//! every [`Scheduler::step`] builds a single ragged spine call that
//! prefills newly admitted prompts *and* decodes one token for every
//! live sequence at once, then samples, then retires finished sequences
//! — the vLLM-style iteration-level scheduling loop, minus the GPU.
//!
//! # Sequence lifecycle
//!
//! ```text
//! waiting ──admit──▶ prefill ──▶ decoding ──stop──▶ finished
//!            ▲  (≤ max_prefill_per_step joins per step,          │
//!            │   ≤ max_active sequences KV-resident,             │
//!            │   and — with a KvPool — only if the step's pages  │
//!            │   fit the byte budget)                            │
//!            └────────────── preempted ◀──evict-at-capacity──────┘
//! ```
//!
//! Stop conditions, checked after each sampled token: the token equals
//! `eos` (kept in the output), `max_new_tokens` reached, or the context
//! window is exhausted ([`FinishReason::ContextFull`] — the final token
//! is still returned; it just cannot be fed back).
//!
//! The scheduler is agnostic to tensor-parallel sharding: a model from
//! [`crate::serve::PackedModel::build_sharded`] fans each fused
//! prefill+decode spine call out across its shard pool and yields the
//! same token streams as `shards = 1` — including under paged-KvPool
//! eviction and requeue, which `rust/tests/shard.rs` pins against the
//! cache-free oracle.
//!
//! # Memory-bounded scheduling
//!
//! When the engine carries a [`crate::serve::KvPool`]
//! ([`DecodeEngine::with_pool`]), every step **reserves** its page cost
//! up front with the pool's exact page arithmetic
//! ([`crate::serve::KvPool::bytes_for_rows`]): admission stops at the
//! first waiting request whose prefill pages don't fit (admission
//! blocks — FIFO order is preserved), and if the live sequences' next
//! decode step itself no longer fits, the **youngest** active sequence
//! is evicted — its pages return to the pool and the request moves to
//! the head of a preempted queue ([`Scheduler::preempted`]) with its
//! sampler state and generated tokens intact. A preempted sequence
//! resumes by re-prefilling `prompt ++ generated` in one ragged call;
//! under the Exact codec the full-prefix exactness contract makes the
//! resumed logits bit-identical to the uninterrupted ones (and under an
//! Mx codec identical under that same codec), so **preemption never
//! changes a token stream** — pinned by `rust/tests/kvpool.rs`. The
//! engine guarantees the budget fits one full-context sequence, so
//! evicting down to a single sequence always makes progress.
//!
//! # Determinism
//!
//! A request's token stream is a pure function of
//! `(weights, qconfig, prompt, sampling policy)`: step logits are
//! bit-identical to the full-prefix reference regardless of which
//! neighbors share the ragged batch (batching invariance + the decode
//! exactness contract), and each request samples from its **own**
//! seeded [`crate::dist::Pcg64`] stream. Admission order, `max_active`,
//! and GEMM threading therefore cannot change any stream —
//! `rust/tests/decode.rs` pins this by permuting all three.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::ensure;

use super::decode::{DecodeEngine, Sampler, Sampling, SeqKv};

/// One generation request.
#[derive(Debug, Clone)]
pub struct DecodeRequest {
    /// Caller-chosen id, echoed in the result (need not be unique, but
    /// results sort by it).
    pub id: u64,
    /// Prompt tokens (`1..=seq_len`).
    pub prompt: Vec<i32>,
    /// Generation budget (≥ 1).
    pub max_new_tokens: usize,
    /// Optional stop token (kept in the output when hit).
    pub eos: Option<i32>,
    pub sampling: Sampling,
}

/// Why a sequence retired.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Sampled the `eos` token.
    Eos,
    /// Generated `max_new_tokens`.
    MaxTokens,
    /// Prompt + generated tokens filled the model's context window.
    ContextFull,
}

/// A finished request: its generated tokens plus per-token timing.
#[derive(Debug, Clone)]
pub struct DecodeResult {
    pub id: u64,
    pub prompt_len: usize,
    /// Generated tokens, in order (includes the `eos` token if hit).
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// Submit → first generated token (includes queueing + prefill).
    pub ttft: Duration,
    /// Gaps between consecutive token emissions (`tokens.len() - 1`
    /// entries) — the inter-token latency samples.
    pub itl: Vec<Duration>,
}

/// Scheduling policy.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// KV-resident sequences decoded concurrently.
    pub max_active: usize,
    /// New prompts prefilled per step — bounds how much prefill work a
    /// single ragged batch mixes into the decode cadence (long prompts
    /// would otherwise stall every live stream's next token).
    pub max_prefill_per_step: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_active: 8, max_prefill_per_step: 2 }
    }
}

struct Active {
    req: DecodeRequest,
    submitted: Instant,
    kv: SeqKv,
    sampler: Sampler,
    /// Generated tokens; the last one is the next decode-step input
    /// (unless the sequence just finished).
    out: Vec<i32>,
    emitted: Vec<Instant>,
}

impl Active {
    /// New cache rows the next step appends for this sequence: the
    /// whole `prompt ++ generated` prefix when the cache is empty
    /// (fresh prefill or a preempted resume), one token otherwise.
    fn step_len(&self) -> usize {
        if self.kv.len() == 0 {
            self.req.prompt.len() + self.out.len()
        } else {
            1
        }
    }
}

/// The continuous-batching driver (module docs). Single-threaded by
/// design — the parallelism lives in the GEMM under the spine, and a
/// deterministic driver is what makes the stream-invariance tests
/// meaningful.
pub struct Scheduler {
    engine: DecodeEngine,
    cfg: SchedulerConfig,
    waiting: VecDeque<(DecodeRequest, Instant)>,
    /// Evicted-at-capacity sequences, resumed before new admissions
    /// (front = most recently evicted = next to resume).
    preempted: VecDeque<Active>,
    active: Vec<Active>,
    finished: Vec<DecodeResult>,
    preemptions: u64,
    peak_kv_bytes: usize,
}

impl Scheduler {
    pub fn new(engine: DecodeEngine, cfg: SchedulerConfig) -> Scheduler {
        Scheduler {
            engine,
            cfg: SchedulerConfig {
                max_active: cfg.max_active.max(1),
                max_prefill_per_step: cfg.max_prefill_per_step.max(1),
            },
            waiting: VecDeque::new(),
            preempted: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            preemptions: 0,
            peak_kv_bytes: 0,
        }
    }

    /// Queue a request (validated against the model's limits).
    pub fn submit(&mut self, req: DecodeRequest) -> crate::Result<()> {
        let dims = *self.engine.model().dims();
        ensure!(
            !req.prompt.is_empty() && req.prompt.len() <= dims.seq_len,
            "prompt length {} out of range 1..={}",
            req.prompt.len(),
            dims.seq_len
        );
        for &t in &req.prompt {
            ensure!(
                t >= 0 && (t as usize) < dims.vocab,
                "prompt token {t} out of vocab range 0..{}",
                dims.vocab
            );
        }
        ensure!(req.max_new_tokens >= 1, "max_new_tokens must be >= 1");
        // fail fast on a bad sampling policy, before admission
        Sampler::new(&req.sampling)?;
        self.waiting.push_back((req, Instant::now()));
        Ok(())
    }

    /// Requests not yet admitted.
    pub fn pending(&self) -> usize {
        self.waiting.len()
    }

    /// Sequences evicted at pool capacity, awaiting resume.
    pub fn preempted(&self) -> usize {
        self.preempted.len()
    }

    /// KV-resident sequences.
    pub fn active(&self) -> usize {
        self.active.len()
    }

    /// Whether no work remains (waiting, preempted, or KV-resident).
    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty()
            && self.preempted.is_empty()
            && self.active.is_empty()
    }

    /// Total resident KV bytes across live sequences (allocated page
    /// bytes when the engine runs on a [`crate::serve::KvPool`]).
    pub fn kv_resident_bytes(&self) -> usize {
        self.active.iter().map(|a| a.kv.resident_bytes()).sum()
    }

    /// High-water mark of [`Scheduler::kv_resident_bytes`] observed
    /// after each step.
    pub fn peak_kv_resident_bytes(&self) -> usize {
        self.peak_kv_bytes
    }

    /// Evict-and-requeue events so far.
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Take the results finished so far (sorted by request id).
    pub fn take_finished(&mut self) -> Vec<DecodeResult> {
        let mut out = std::mem::take(&mut self.finished);
        out.sort_by_key(|r| r.id);
        out
    }

    /// Exact page bytes the next spine call over `active` allocates
    /// (0 without a pool — inline caches are unbounded).
    fn planned_step_bytes(&self) -> usize {
        let Some(pool) = self.engine.pool() else { return 0 };
        self.active
            .iter()
            .map(|a| pool.bytes_for_rows(a.kv.len(), a.step_len()))
            .sum()
    }

    /// Whether the live set's next step plus `extra` additional fresh
    /// prefill rows fits the pool budget (vacuously true without one).
    fn step_fits(&self, extra_prefill_rows: usize) -> bool {
        match self.engine.pool() {
            None => true,
            Some(pool) => {
                self.planned_step_bytes()
                    + pool.bytes_for_positions(extra_prefill_rows)
                    <= pool.free_bytes()
            }
        }
    }

    /// Run one scheduling iteration: admit (within KV slots *and* the
    /// pool's page budget), evict-and-requeue if the live set outgrew
    /// the pool, one ragged forward (prefill + decode fused), sample,
    /// retire. Returns the number of tokens generated — 0 means nothing
    /// could run: either fully idle, or every admission is blocked on
    /// pool pages held *outside* this scheduler (check
    /// [`Scheduler::is_idle`] to tell the two apart; [`Scheduler::run`]
    /// errors on the latter instead of spinning).
    pub fn step(&mut self) -> crate::Result<usize> {
        // admit up to the prefill budget while KV slots are free and —
        // with a pool — while the candidate's prefill pages fit on top
        // of the live set's planned step. Preempted sequences resume
        // first (they hold generated tokens); then waiting requests in
        // FIFO order, blocking at the first one that doesn't fit.
        let mut admitted = 0usize;
        while self.active.len() < self.cfg.max_active
            && admitted < self.cfg.max_prefill_per_step
        {
            if let Some(a) = self.preempted.front() {
                if !self.step_fits(a.step_len()) {
                    break;
                }
                let a = self.preempted.pop_front().unwrap();
                self.active.push(a);
                admitted += 1;
                continue;
            }
            let Some((req, _)) = self.waiting.front() else { break };
            if !self.step_fits(req.prompt.len()) {
                break;
            }
            let (req, submitted) = self.waiting.pop_front().unwrap();
            let sampler = Sampler::new(&req.sampling)?;
            self.active.push(Active {
                req,
                submitted,
                kv: self.engine.new_kv(),
                sampler,
                out: Vec::new(),
                emitted: Vec::new(),
            });
            admitted += 1;
        }
        if self.active.is_empty() {
            return Ok(0);
        }

        // at capacity the live set itself may no longer fit (decode
        // growth crossing page boundaries): evict the youngest sequence
        // — free its pages, requeue it with sampler + tokens intact —
        // until the step fits. The engine's budget invariant (one full
        // sequence always fits) bounds this at one survivor.
        while !self.step_fits(0) {
            // the engine's budget invariant guarantees one sequence
            // *alone* always fits, so reaching zero evictable neighbors
            // means the shortfall is external: the process-wide pool's
            // pages are held by sequences outside this scheduler
            ensure!(
                self.active.len() > 1,
                "scheduler blocked: the KV pool cannot fit the last live \
                 sequence's next step — its pages are held outside this \
                 scheduler (free them or raise the budget)"
            );
            let mut victim = self.active.pop().unwrap();
            victim.kv.reset();
            self.preempted.push_front(victim);
            self.preemptions += 1;
        }

        // one ragged spine call: the full `prompt ++ generated` prefix
        // for fresh and resumed sequences, one token for live ones
        let mut tokens = Vec::new();
        let mut lens = Vec::with_capacity(self.active.len());
        for a in &self.active {
            if a.kv.len() == 0 {
                tokens.extend_from_slice(&a.req.prompt);
                tokens.extend_from_slice(&a.out);
                lens.push(a.req.prompt.len() + a.out.len());
            } else {
                tokens.push(*a.out.last().expect("decoding seq has a token"));
                lens.push(1);
            }
        }
        let mut kvs: Vec<SeqKv> = self
            .active
            .iter_mut()
            .map(|a| std::mem::take(&mut a.kv))
            .collect();
        let logits = match self.engine.step_ragged(&tokens, &lens, &mut kvs) {
            Ok(logits) => {
                for (a, kv) in self.active.iter_mut().zip(kvs) {
                    a.kv = kv;
                }
                logits
            }
            Err(e) => {
                // a failed forward may leave partial K/V rows in the
                // caches (forward_ragged's contract) — they are
                // unusable, so the in-flight sequences are dropped
                // rather than resumed against corrupt state. submit()
                // validation makes this unreachable in practice.
                self.active.clear();
                return Err(e);
            }
        };
        let now = Instant::now();
        self.peak_kv_bytes = self.peak_kv_bytes.max(self.kv_resident_bytes());
        let vocab = self.engine.model().dims().vocab;
        let seq_cap = self.engine.model().dims().seq_len;

        // sample one token per sequence, then retire finished ones
        let mut produced = 0usize;
        let mut b = 0usize;
        let mut i = 0usize;
        while i < self.active.len() {
            let a = &mut self.active[i];
            let tok = a.sampler.pick(&logits[b * vocab..(b + 1) * vocab]);
            a.out.push(tok);
            a.emitted.push(now);
            produced += 1;
            b += 1;
            let finish = if a.req.eos == Some(tok) {
                Some(FinishReason::Eos)
            } else if a.out.len() >= a.req.max_new_tokens {
                Some(FinishReason::MaxTokens)
            } else if a.kv.len() >= seq_cap {
                // the sampled token has no position left to occupy
                Some(FinishReason::ContextFull)
            } else {
                None
            };
            match finish {
                Some(f) => {
                    let done = self.active.remove(i);
                    self.finished.push(finalize(done, f));
                }
                None => i += 1,
            }
        }
        Ok(produced)
    }

    /// Drive [`Scheduler::step`] until every submitted request has
    /// finished; returns all results sorted by request id.
    ///
    /// Errors instead of spinning if the scheduler can make no progress
    /// — possible only when the KV pool's pages are held by sequences
    /// *outside* this scheduler (the pool is process-wide), since the
    /// engine's budget invariant guarantees this scheduler's own
    /// sequences alone can always advance.
    pub fn run(&mut self) -> crate::Result<Vec<DecodeResult>> {
        while !self.is_idle() {
            let produced = self.step()?;
            ensure!(
                produced > 0 || self.is_idle(),
                "scheduler blocked: the KV pool has no room for the next \
                 request's prefill and no live sequence to evict — pages \
                 are held outside this scheduler (free them or raise the \
                 budget)"
            );
        }
        Ok(self.take_finished())
    }
}

fn finalize(a: Active, finish: FinishReason) -> DecodeResult {
    let ttft = a
        .emitted
        .first()
        .map(|t| t.duration_since(a.submitted))
        .unwrap_or_default();
    let itl = a
        .emitted
        .windows(2)
        .map(|w| w[1].duration_since(w[0]))
        .collect();
    DecodeResult {
        id: a.req.id,
        prompt_len: a.req.prompt.len(),
        tokens: a.out,
        finish,
        ttft,
        itl,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::Params;
    use crate::runtime::artifacts::ModelDims;
    use crate::runtime::qconfig::{PerLayerQConfig, QConfig};
    use crate::serve::cache::OperandCache;
    use crate::serve::packed_model::PackedModel;
    use std::sync::Arc;

    fn engine() -> DecodeEngine {
        let dims = ModelDims {
            vocab: 32,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            d_ff: 64,
            seq_len: 8,
        };
        let params = Params::init_surrogate(&dims, 33);
        let cache = OperandCache::new(32);
        let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap());
        let model = Arc::new(
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap(),
        );
        DecodeEngine::new(model).unwrap()
    }

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> DecodeRequest {
        DecodeRequest {
            id,
            prompt,
            max_new_tokens: max_new,
            eos: None,
            sampling: Sampling::Greedy,
        }
    }

    #[test]
    fn drains_more_requests_than_slots() {
        let mut s = Scheduler::new(
            engine(),
            SchedulerConfig { max_active: 2, max_prefill_per_step: 1 },
        );
        for id in 0..5 {
            s.submit(req(id, vec![1, 2, 3], 3)).unwrap();
        }
        assert_eq!(s.pending(), 5);
        let results = s.run().unwrap();
        assert_eq!(results.len(), 5);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), 3);
            assert_eq!(r.finish, FinishReason::MaxTokens);
            assert_eq!(r.itl.len(), 2);
        }
        assert_eq!((s.pending(), s.active()), (0, 0));
        assert_eq!(s.kv_resident_bytes(), 0);
    }

    #[test]
    fn context_full_stops_generation() {
        let mut s = Scheduler::new(engine(), SchedulerConfig::default());
        // prompt fills 7 of 8 positions: token 1 lands the cache at 8
        // after the feed-back step, so exactly 2 tokens fit
        s.submit(req(9, vec![0; 7], 100)).unwrap();
        let r = &s.run().unwrap()[0];
        assert_eq!(r.tokens.len(), 2);
        assert_eq!(r.finish, FinishReason::ContextFull);
        // a full-window prompt still yields exactly one token
        s.submit(req(10, vec![0; 8], 100)).unwrap();
        let r = &s.run().unwrap()[0];
        assert_eq!(r.tokens.len(), 1);
        assert_eq!(r.finish, FinishReason::ContextFull);
    }

    #[test]
    fn submit_validates_requests() {
        let mut s = Scheduler::new(engine(), SchedulerConfig::default());
        assert!(s.submit(req(0, vec![], 3)).is_err());
        assert!(s.submit(req(0, vec![0; 9], 3)).is_err());
        assert!(s.submit(req(0, vec![99], 3)).is_err());
        assert!(s.submit(req(0, vec![1], 0)).is_err());
        let bad_temp = DecodeRequest {
            sampling: Sampling::Temperature { temp: -1.0, seed: 0 },
            ..req(0, vec![1], 3)
        };
        assert!(s.submit(bad_temp).is_err());
        assert_eq!(s.pending(), 0);
    }
}
