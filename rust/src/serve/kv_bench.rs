//! The `microscale kv-bench` driver: memory-bounded KV-cached
//! generation at a **fixed page budget**, Exact f32 KV pages vs
//! FP8-quantized vs FP4-quantized ([`super::kvpool`]).
//!
//! Per KV codec the driver (1) builds one shared [`PackedModel`]
//! (weights at FP4/UE5M3 through the operand cache), (2) builds a
//! [`KvPool`] with that codec and the **same byte budget** as every
//! other config, (3) gates on correctness — the Exact config's
//! scheduler streams must equal the cache-free
//! [`generate_reforward`] oracle bit for bit even through
//! evict-and-requeue, and every Mx config must be self-consistent
//! (token-by-token stepping bit-identical to one whole-prefix call
//! under the same codec) — then (4) drives the [`Scheduler`] and
//! records tok/s, TTFT/ITL percentiles, **peak resident KV bytes**,
//! preemptions, and the pool's allocation counters. Results land in
//! machine-readable **`BENCH_kv.json`** (field map in EXPERIMENTS.md
//! §Perf).
//!
//! The `pass` verdict is host-independent (unlike the speed-target
//! benches): all correctness gates passed, every peak stayed within the
//! budget, and the measured per-position storage ordered
//! FP4 < FP8 < Exact.
//!
//! Shared by the CLI subcommand and `cargo bench --bench kv_bench`.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Context;

use super::cache::operand_cache;
use super::decode::{generate_reforward, DecodeEngine, Sampling};
use super::decode_bench::bench_dims;
use super::kvpool::KvPool;
use super::packed_model::PackedModel;
use super::scheduler::{DecodeRequest, Priority, Scheduler, SchedulerConfig};
use crate::stats::percentiles;
use crate::dist::Pcg64;
use crate::model::weights::Params;
use crate::runtime::qconfig::{PerLayerQConfig, QConfig};
use crate::util::json::{self, Json};

/// Driver options (CLI flags map onto these).
#[derive(Debug, Clone)]
pub struct KvBenchOpts {
    /// CI-sized run: tiny model, tiny traffic.
    pub smoke: bool,
    /// Report path (`BENCH_kv.json` in the working directory).
    pub out: PathBuf,
    /// Concurrent-sequence cap (`max_active`).
    pub concurrency: usize,
    /// Prompt tokens per request.
    pub prompt_len: usize,
    /// Generation budget per request.
    pub max_new: usize,
    /// Total requests per config.
    pub requests: usize,
    /// Cache rows per pool page.
    pub page_rows: usize,
    /// Pool byte budget in units of one full-context **Exact** sequence
    /// (the same byte budget is then applied to every codec, which is
    /// the point of the comparison).
    pub budget_seqs: f64,
    /// Override the global block size (tuned configs carry their own
    /// via `--qconfig-file`).
    pub block_size: Option<usize>,
    /// Tuned entry from `microscale tune` (`--qconfig-file`): the
    /// weight config replaces the default FP4/UE5M3 model, and the KV
    /// codec id (`"none"` for exact) is appended to the codec axis as
    /// `tuned_kv`.
    pub tuned: Option<(PerLayerQConfig, String)>,
}

impl KvBenchOpts {
    pub fn new(smoke: bool) -> KvBenchOpts {
        KvBenchOpts {
            smoke,
            out: PathBuf::from("BENCH_kv.json"),
            concurrency: if smoke { 3 } else { 8 },
            prompt_len: if smoke { 4 } else { 32 },
            max_new: if smoke { 6 } else { 32 },
            requests: if smoke { 4 } else { 16 },
            page_rows: if smoke { 8 } else { 16 },
            budget_seqs: if smoke { 1.5 } else { 3.0 },
            block_size: None,
            tuned: None,
        }
    }
}

/// The KV codec axis: Exact f32 pages, FP8 codes, FP4 codes — UE5M3
/// scales for the quantized ones (the paper's proposal; KV activations
/// are exactly the narrow-distribution regime it exists for).
fn kv_configs() -> crate::Result<Vec<(&'static str, PerLayerQConfig)>> {
    Ok(vec![
        ("exact_kv", PerLayerQConfig::uniform(QConfig::baseline())),
        (
            "fp8_kv",
            PerLayerQConfig::uniform(QConfig::named(
                "fp8_e4m3", "ue5m3", false,
            )?),
        ),
        ("fp4_kv", PerLayerQConfig::uniform(QConfig::fp4("ue5m3")?)),
    ])
}

fn prompt(rng: &mut Pcg64, vocab: usize, len: usize) -> Vec<i32> {
    (0..len).map(|_| (rng.next_u64() % vocab as u64) as i32).collect()
}

/// Exact-codec gate: budget-constrained scheduling (admission blocking
/// + evict-and-requeue included) must not change a single token vs the
/// cache-free full-prefix oracle.
fn exact_stream_gate(
    model: &Arc<PackedModel>,
    pool: &Arc<KvPool>,
    prompt_len: usize,
    max_new: usize,
    rng: &mut Pcg64,
) -> crate::Result<()> {
    let vocab = model.dims().vocab;
    let prompts: Vec<Vec<i32>> =
        (0..4).map(|_| prompt(rng, vocab, prompt_len)).collect();
    let want: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| generate_reforward(model, p, max_new, None, &Sampling::Greedy))
        .collect::<crate::Result<_>>()?;
    let mut sched = Scheduler::new(
        DecodeEngine::with_pool(model.clone(), pool.clone())?,
        SchedulerConfig {
            max_active: 4,
            max_prefill_per_step: 4,
            ..SchedulerConfig::default()
        },
    );
    for (id, p) in prompts.iter().enumerate() {
        sched.submit(DecodeRequest {
            id: id as u64,
            prompt: p.clone(),
            max_new_tokens: max_new,
            eos: None,
            sampling: Sampling::Greedy,
            priority: Priority::Interactive,
        })?;
    }
    let results = sched.run()?;
    for (r, w) in results.iter().zip(&want) {
        anyhow::ensure!(
            r.tokens == *w,
            "exact_kv: budget-constrained stream {:?} != re-forward oracle \
             {w:?} (request {})",
            r.tokens,
            r.id
        );
    }
    Ok(())
}

/// Mx-codec gate: token-by-token stepping and one whole-prefix ragged
/// call must agree bit for bit under the same codec (the codec-relative
/// exactness contract of DESIGN.md §11).
fn mx_consistency_gate(
    label: &str,
    model: &Arc<PackedModel>,
    pool: &Arc<KvPool>,
    rng: &mut Pcg64,
) -> crate::Result<()> {
    let dims = *model.dims();
    let steps = 5usize.min(dims.seq_len.saturating_sub(4));
    let toks = prompt(rng, dims.vocab, 4 + steps);
    let engine = DecodeEngine::with_pool(model.clone(), pool.clone())?;
    let mut kv = engine.new_kv();
    let mut stepped = engine.prefill(&toks[..4], &mut kv)?;
    for t in 4..4 + steps {
        stepped = engine.step(&[toks[t]], std::slice::from_mut(&mut kv))?;
    }
    drop(kv);
    let mut kv2 = engine.new_kv();
    let whole = engine.prefill(&toks, &mut kv2)?;
    anyhow::ensure!(
        stepped
            .iter()
            .zip(&whole)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "{label}: stepped decode diverges from whole-prefix under the same \
         KV codec — refusing to time"
    );
    Ok(())
}

/// Run the bench and write the report; returns the report JSON.
pub fn run(opts: &KvBenchOpts) -> crate::Result<Json> {
    let dims = bench_dims(opts.smoke);
    let block_size = opts
        .block_size
        .unwrap_or(if opts.smoke { 16 } else { 32 });
    anyhow::ensure!(
        opts.prompt_len >= 1 && opts.prompt_len < dims.seq_len,
        "prompt length {} leaves no room to generate (seq_len {})",
        opts.prompt_len,
        dims.seq_len
    );
    let params = Params::init_surrogate(&dims, 2026);
    let weights = match &opts.tuned {
        Some((w, _)) => w.clone(),
        None => PerLayerQConfig::uniform(QConfig::fp4("ue5m3")?),
    };
    let model = Arc::new(PackedModel::build(
        &dims,
        &params,
        &weights,
        block_size,
        operand_cache(),
    )?);

    // one byte budget for every codec, denominated in full-context
    // Exact sequences; below 1.0 even the Exact engine would refuse the
    // pool (deadlock risk), so reject the flag instead of clamping it
    anyhow::ensure!(
        opts.budget_seqs >= 1.0,
        "--budget-seqs {} must be >= 1.0: the budget has to hold at least \
         one full-context sequence",
        opts.budget_seqs
    );
    let exact_probe = KvPool::exact(&dims, opts.page_rows, usize::MAX)?;
    let exact_seq_bytes = exact_probe.bytes_for_positions(dims.seq_len);
    let budget =
        (exact_seq_bytes as f64 * opts.budget_seqs).ceil() as usize;
    let mut rng = Pcg64::new(0xCAFE);

    println!(
        "== kv-bench ({}) : {} layers, d_model {}, seq {}, weights {}, \
         page {} rows, budget {} B ({} full Exact seqs), {} requests at \
         c{} ==",
        if opts.smoke { "smoke" } else { "full" },
        dims.n_layers,
        dims.d_model,
        dims.seq_len,
        weights.id(),
        opts.page_rows,
        budget,
        opts.budget_seqs,
        opts.requests,
        opts.concurrency,
    );

    let mut config_entries: Vec<(String, Json)> = Vec::new();
    let mut position_bytes: Vec<(String, usize)> = Vec::new();
    let mut accounting_ok = true;
    let mut codec_axis: Vec<(String, PerLayerQConfig)> = kv_configs()?
        .into_iter()
        .map(|(l, c)| (l.to_string(), c))
        .collect();
    if let Some((_, kv_id)) = &opts.tuned {
        let codec = if kv_id == "none" {
            QConfig::baseline()
        } else {
            QConfig::parse(kv_id)
                .with_context(|| format!("tuned kv codec {kv_id:?}"))?
        };
        codec_axis.push((
            "tuned_kv".to_string(),
            PerLayerQConfig::uniform(codec),
        ));
    }
    for (label, kv_cfg) in &codec_axis {
        let mk_pool = || {
            KvPool::build(&dims, &kv_cfg, block_size, opts.page_rows, budget)
        };
        let gate_pool = mk_pool()?;
        if gate_pool.is_exact() {
            exact_stream_gate(
                &model,
                &gate_pool,
                opts.prompt_len,
                opts.max_new,
                &mut rng,
            )?;
        } else {
            mx_consistency_gate(label, &model, &gate_pool, &mut rng)?;
        }
        // a fresh pool for the timed run, so the reported counters
        // cover only the measured traffic
        let pool = mk_pool()?;
        println!(
            "\n-- {label} ({}) : {} B/position, gate OK",
            pool.codec_id(0),
            pool.position_bytes(),
        );

        let mut sched = Scheduler::new(
            DecodeEngine::with_pool(model.clone(), pool.clone())?,
            SchedulerConfig {
                max_active: opts.concurrency,
                max_prefill_per_step: opts.concurrency,
                ..SchedulerConfig::default()
            },
        );
        let t0 = Instant::now();
        for id in 0..opts.requests {
            sched.submit(DecodeRequest {
                id: id as u64,
                prompt: prompt(&mut rng, dims.vocab, opts.prompt_len),
                max_new_tokens: opts.max_new,
                eos: None,
                sampling: Sampling::Temperature {
                    temp: 0.9,
                    seed: 0xB0B ^ id as u64,
                },
                priority: Priority::Interactive,
            })?;
        }
        let results = sched.run()?;
        let secs = t0.elapsed().as_secs_f64();
        let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
        let tok_s = tokens as f64 / secs.max(1e-9);
        let mut ttft: Vec<f64> =
            results.iter().map(|r| r.ttft.as_secs_f64() * 1e3).collect();
        let mut itl: Vec<f64> = results
            .iter()
            .flat_map(|r| r.itl.iter().map(|d| d.as_secs_f64() * 1e3))
            .collect();
        let peak = sched.peak_kv_resident_bytes();
        let stats = pool.stats();
        let [ttft_p50, ttft_p95] = percentiles(&mut ttft, [50.0, 95.0]);
        let [itl_p50, itl_p95] = percentiles(&mut itl, [50.0, 95.0]);
        // two independent accountings must agree: the allocator's
        // high-water mark vs the scheduler's per-sequence residency sum
        // (pages only move inside spine calls, which end exactly where
        // the scheduler samples), and the pool must drain to zero once
        // every request retires — a page leak or double-charge breaks
        // either. (`peak <= budget` is an allocator invariant and would
        // be a vacuous check.)
        accounting_ok &= stats.peak_bytes == peak && pool.used_bytes() == 0;
        println!(
            "   {tok_s:8.1} tok/s  ttft p50 {:6.1} ms  itl p50 {:6.2} ms  \
             peak KV {peak} B ({:.0}% of budget)  {} preemptions",
            ttft_p50,
            itl_p50,
            100.0 * peak as f64 / budget as f64,
            sched.preemptions(),
        );
        position_bytes.push((label.to_string(), pool.position_bytes()));
        config_entries.push((
            label.to_string(),
            json::obj(vec![
                ("kv_codec", json::s(&pool.codec_id(0))),
                // which correctness gate this config passed: only the
                // Exact codec is bit-exact against the oracle; Mx
                // codecs are verified self-consistent under their own
                // stated error model (don't reuse the bit_exact name —
                // it would misread as oracle exactness)
                (
                    "gate",
                    json::s(if pool.is_exact() {
                        "oracle-stream-bit-exact"
                    } else {
                        "codec-self-consistency"
                    }),
                ),
                ("gate_passed", Json::Bool(true)),
                ("position_bytes", json::num(pool.position_bytes() as f64)),
                (
                    "bytes_vs_exact",
                    json::num(
                        pool.position_bytes() as f64
                            / exact_probe.position_bytes() as f64,
                    ),
                ),
                ("requests", json::num(opts.requests as f64)),
                ("tokens", json::num(tokens as f64)),
                ("tok_per_s", json::num(tok_s)),
                ("ttft_p50_ms", json::num(ttft_p50)),
                ("ttft_p95_ms", json::num(ttft_p95)),
                ("itl_p50_ms", json::num(itl_p50)),
                ("itl_p95_ms", json::num(itl_p95)),
                ("kv_peak_bytes", json::num(peak as f64)),
                ("preemptions", json::num(sched.preemptions() as f64)),
                (
                    "pool",
                    json::obj(vec![
                        ("allocs", json::num(stats.allocs as f64)),
                        ("frees", json::num(stats.frees as f64)),
                        (
                            "failed_allocs",
                            json::num(stats.failed_allocs as f64),
                        ),
                        ("peak_bytes", json::num(stats.peak_bytes as f64)),
                    ]),
                ),
            ]),
        ));
    }

    // host-independent verdict: gates passed, budget respected, and the
    // storage ordering FP4 < FP8 < Exact measured on real page bytes
    let by_label = |l: &str| {
        position_bytes.iter().find(|(n, _)| n == l).map(|(_, b)| *b)
    };
    let ordering_ok = match (
        by_label("fp4_kv"),
        by_label("fp8_kv"),
        by_label("exact_kv"),
    ) {
        (Some(fp4), Some(fp8), Some(exact)) => fp4 < fp8 && fp8 < exact,
        _ => false,
    };
    // the correctness gates error out above, so reaching here means
    // they all passed
    let pass = accounting_ok && ordering_ok;
    println!(
        "\n   verdict (gates + allocator/scheduler accounting agreement + \
         FP4 < FP8 < Exact bytes/position): {}",
        if pass { "PASS" } else { "MISS" }
    );
    let report = json::obj(vec![
        ("bench", json::s("kv")),
        ("smoke", Json::Bool(opts.smoke)),
        // the vector kernel the KV page codec (and every packed GEMM)
        // dispatched to in this run (ISSUE 7 simd axis)
        ("simd_kernel", json::s(crate::util::simd::kernel_name())),
        (
            "model",
            json::obj(vec![
                ("vocab", json::num(dims.vocab as f64)),
                ("d_model", json::num(dims.d_model as f64)),
                ("n_heads", json::num(dims.n_heads as f64)),
                ("n_layers", json::num(dims.n_layers as f64)),
                ("d_ff", json::num(dims.d_ff as f64)),
                ("seq_len", json::num(dims.seq_len as f64)),
                ("block_size", json::num(block_size as f64)),
            ]),
        ),
        ("weights_qconfig", json::s(&weights.id())),
        ("prompt_len", json::num(opts.prompt_len as f64)),
        ("max_new", json::num(opts.max_new as f64)),
        ("concurrency", json::num(opts.concurrency as f64)),
        ("page_rows", json::num(opts.page_rows as f64)),
        ("budget_bytes", json::num(budget as f64)),
        ("exact_seq_bytes", json::num(exact_seq_bytes as f64)),
        ("configs", json::obj_owned(config_entries)),
        // deterministic storage/exactness verdict — meaningful on smoke
        // shapes too, unlike the host-dependent speed targets
        ("pass", Json::Bool(pass)),
    ]);
    std::fs::write(&opts.out, report.to_string())
        .with_context(|| format!("writing {}", opts.out.display()))?;
    println!("   wrote {}", opts.out.display());
    Ok(report)
}
