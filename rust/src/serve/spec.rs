//! Cross-precision speculative decoding: a cheap draft config proposes,
//! the target config verifies — with **bit-identical output streams**.
//!
//! The repo holds several bit-exact execution paths for one weight
//! source (packed FP4, packed FP8, Exact bf16 — DESIGN.md §§6–8).
//! [`SpecDecodeEngine`] exploits that: a *draft* [`PackedModel`]
//! (default FP4/UE5M3 — the cheapest packed path) proposes `k` tokens
//! through the ordinary m == 1 decode fast path, then the *target*
//! model verifies all `k + 1` positions in **one** ragged
//! [`PackedModel::forward_ragged`] call (the PR-4 multi-token append:
//! row `j` of a ragged feed is bit-identical to the last row of a
//! full-prefix forward over the prefix up to `j`, independent of the
//! tokens fed after it — causal attention never looks right).
//!
//! # Why the emitted stream is bit-identical to non-speculative decode
//!
//! Acceptance is **replay acceptance**: at every verified position the
//! request's own [`Sampler`] — greedy argmax, or the seeded-Pcg64
//! temperature sampler — picks a token from the *target* logits row,
//! exactly as non-speculative decode would have (same logits bits by
//! the append contract above, same RNG state because one uniform is
//! drawn per emitted token in emission order, never for tokens that
//! are not emitted). The draft proposal is then compared to that pick:
//! a match means the window continues (the draft predicted the
//! sampler), a mismatch emits the sampler's pick and discards the rest
//! of the window. Every emitted token is therefore *the* token
//! non-speculative decode emits, bit for bit, for every speculation
//! depth `k`, every draft config, and every thread/shard count — the
//! draft can only change *how fast* tokens appear, never *which*
//! tokens. `rust/tests/spec.rs` pins this against the cache-free
//! oracle ([`super::decode::generate_reforward`]).
//!
//! Rejected draft rows leave garbage K/V rows in both caches; the
//! round rolls them back with [`SeqKv::truncate`] (paged caches free
//! whole pages and privatize a shared tail — [`super::kvpool`] docs).
//!
//! # Acceptance rate as a paper lens
//!
//! The draft proposes its argmax. For a greedy target the acceptance
//! rate is exactly the probability the draft config's argmax equals
//! the target's — a direct, in-vivo measure of how far the draft
//! quantization bends the output distribution. Sweeping the draft over
//! the paper's {FP4, FP8} × {UE4M3, UE5M3} × block-size grid
//! (`microscale spec-bench`) turns the block-size anomaly into an
//! acceptance-rate curve: "finer is better" predicts acceptance rising
//! as blocks shrink; the UE4M3 inversion predicts collapse below the
//! threshold.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::ensure;

use super::decode::{DecodeEngine, Sampler, Sampling};
use super::kvpool::KvPool;
use super::packed_model::{PackedModel, SeqKv};

/// Greedy argmax with the [`Sampler`] tie-break (lowest index wins) —
/// the draft's proposal rule. Deterministic and seed-free, so draft
/// proposals are invariant to everything the decode contract is.
pub(crate) fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &l) in row.iter().enumerate() {
        if l > row[best] {
            best = i;
        }
    }
    best as i32
}

/// Replay-acceptance over one verify window (module docs): sample each
/// target logits row with the request's own sampler, in order, stopping
/// at the first draft mismatch (the sampler's pick is emitted in its
/// place), on `eos`, or after `max_emit` tokens. `logits` holds
/// `drafts.len() + 1` rows of `vocab`; returns the emitted tokens and
/// how many draft proposals were accepted. The sampler draws exactly
/// one uniform per emitted token — never for unemitted rows — so its
/// RNG state stays in lockstep with non-speculative decode.
pub(crate) fn accept_window(
    sampler: &mut Sampler,
    logits: &[f32],
    vocab: usize,
    drafts: &[i32],
    eos: Option<i32>,
    max_emit: usize,
) -> (Vec<i32>, usize) {
    debug_assert_eq!(logits.len(), (drafts.len() + 1) * vocab);
    let mut emitted = Vec::with_capacity(drafts.len() + 1);
    let mut accepted = 0usize;
    for j in 0..=drafts.len() {
        if emitted.len() >= max_emit {
            break;
        }
        let tok = sampler.pick(&logits[j * vocab..(j + 1) * vocab]);
        emitted.push(tok);
        if eos == Some(tok) {
            break;
        }
        if j < drafts.len() && tok == drafts[j] {
            accepted += 1;
        } else {
            break;
        }
    }
    (emitted, accepted)
}

/// One speculative generation's result and counters.
#[derive(Debug, Clone)]
pub struct SpecOutput {
    /// The emitted stream — bit-identical to non-speculative decode.
    pub tokens: Vec<i32>,
    /// Draft tokens proposed across all rounds.
    pub proposed: usize,
    /// Draft tokens accepted (emitted because the sampler agreed).
    pub accepted: usize,
    /// Speculation rounds run (one target verify call each).
    pub rounds: usize,
    /// Wall time inside draft forwards (the speculation overhead).
    pub draft_time: Duration,
    /// Wall time inside target forwards (prefill + verify calls).
    pub verify_time: Duration,
}

impl SpecOutput {
    /// Accepted / proposed (1.0 when nothing was proposed).
    pub fn acceptance(&self) -> f64 {
        if self.proposed == 0 {
            1.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }
}

/// Speculative decoding over two [`PackedModel`]s built from one weight
/// source (module docs): `draft` proposes up to `k` greedy tokens per
/// round, `target` verifies the whole window in one ragged call, and
/// replay acceptance keeps the emitted stream bit-identical to
/// non-speculative decode under the target model.
pub struct SpecDecodeEngine {
    target: DecodeEngine,
    draft: DecodeEngine,
    k: usize,
}

impl SpecDecodeEngine {
    /// Wrap a target/draft model pair with inline (unbounded) caches.
    /// Both models must share one shape — they are the same weights
    /// under different quant configs — and both must satisfy the
    /// KV-cached decode contract ([`DecodeEngine::new`]'s per-tensor
    /// activation-scaling refusal applies to each).
    pub fn new(
        target: Arc<PackedModel>,
        draft: Arc<PackedModel>,
        k: usize,
    ) -> crate::Result<SpecDecodeEngine> {
        Self::build(target, draft, k, None)
    }

    /// Like [`SpecDecodeEngine::new`], but both caches allocate from
    /// `pool` — the target sequence under the pool's primary codec
    /// bank, the draft sequence under its draft bank
    /// ([`KvPool::build_spec`]). The budget must fit one full-context
    /// sequence of each so a lone generation can always finish.
    pub fn with_pool(
        target: Arc<PackedModel>,
        draft: Arc<PackedModel>,
        k: usize,
        pool: Arc<KvPool>,
    ) -> crate::Result<SpecDecodeEngine> {
        Self::build(target, draft, k, Some(pool))
    }

    fn build(
        target: Arc<PackedModel>,
        draft: Arc<PackedModel>,
        k: usize,
        pool: Option<Arc<KvPool>>,
    ) -> crate::Result<SpecDecodeEngine> {
        ensure!(k >= 1, "speculation depth k must be >= 1 (got {k})");
        ensure!(
            target.dims() == draft.dims(),
            "draft and target models must share one shape: {:?} vs {:?}",
            target.dims(),
            draft.dims()
        );
        let seq_len = target.dims().seq_len;
        if let Some(p) = &pool {
            ensure!(
                p.has_draft_bank(),
                "speculative decoding over a pool needs a draft codec \
                 bank (build it with KvPool::build_spec)"
            );
            let worst = p.bytes_for_positions(seq_len)
                + p.draft_bytes_for_rows(0, seq_len);
            ensure!(
                worst <= p.budget_bytes(),
                "KV pool budget {} cannot hold one full-context target + \
                 draft pair ({worst} bytes) — speculation could deadlock",
                p.budget_bytes()
            );
        }
        // the draft engine stays pool-less: its caches come from the
        // shared pool's draft bank (new_draft_kv), not DecodeEngine
        let draft = DecodeEngine::new(draft)?;
        let target = match pool {
            Some(p) => DecodeEngine::with_pool(target, p)?,
            None => DecodeEngine::new(target)?,
        };
        Ok(SpecDecodeEngine { target, draft, k })
    }

    /// The verify-side engine (its pool, model, and caches).
    pub fn target(&self) -> &DecodeEngine {
        &self.target
    }

    /// The draft-side model.
    pub fn draft_model(&self) -> &Arc<PackedModel> {
        self.draft.model()
    }

    /// Speculation depth (draft proposals per round).
    pub fn depth(&self) -> usize {
        self.k
    }

    /// A draft cache: the pool's draft bank when pooled, inline
    /// otherwise.
    pub fn new_draft_kv(&self) -> crate::Result<SeqKv> {
        match self.target.pool() {
            Some(p) => p.draft_seq(),
            None => Ok(self.draft.model().new_kv()),
        }
    }

    /// Generate up to `max_new` tokens speculatively. The returned
    /// stream is bit-identical to
    /// [`super::decode::generate_reforward`] /
    /// single-sequence scheduler decode under the target model for the
    /// same `(prompt, eos, sampling)` — speculation changes throughput,
    /// never tokens (module docs).
    pub fn generate(
        &self,
        prompt: &[i32],
        max_new: usize,
        eos: Option<i32>,
        sampling: &Sampling,
    ) -> crate::Result<SpecOutput> {
        ensure!(!prompt.is_empty(), "empty prompt");
        let dims = *self.target.model().dims();
        ensure!(
            prompt.len() <= dims.seq_len,
            "prompt ({} tokens) exceeds the context window ({})",
            prompt.len(),
            dims.seq_len
        );
        let vocab = dims.vocab;
        let mut sampler = Sampler::new(sampling)?;
        let mut tkv = self.target.new_kv();
        let mut dkv = self.new_draft_kv()?;
        // `prefix` is prompt ++ emitted; the target cache always holds
        // its first `prefix.len() - 1` rows (the last token is pending
        // — its row is produced by the next verify call).
        let mut prefix = prompt.to_vec();
        let mut out = Vec::with_capacity(max_new);
        let mut proposed = 0usize;
        let mut accepted_total = 0usize;
        let mut rounds = 0usize;
        let mut draft_time = Duration::ZERO;
        let mut verify_time = Duration::ZERO;
        if prefix.len() > 1 {
            let t0 = Instant::now();
            self.target.prefill(&prefix[..prefix.len() - 1], &mut tkv)?;
            verify_time += t0.elapsed();
        }
        while out.len() < max_new {
            rounds += 1;
            // a verify window needs k_r + 1 context rows and can emit
            // at most k_r + 1 tokens; cap it by the generation budget
            // and the remaining context so no row is ever wasted
            let remaining_new = max_new - out.len();
            let ctx_room = dims.seq_len - tkv.len();
            let k_r = self
                .k
                .min(remaining_new.saturating_sub(1))
                .min(ctx_room.saturating_sub(1));
            let mut drafts = Vec::with_capacity(k_r);
            if k_r > 0 {
                let t0 = Instant::now();
                // catch-up feed: everything the draft cache has not
                // seen (≥ 1 token — it ends with the pending token);
                // after a fresh start this is the whole prompt
                let mut dl =
                    self.draft.prefill(&prefix[dkv.len()..], &mut dkv)?;
                loop {
                    let d = argmax(&dl);
                    drafts.push(d);
                    if drafts.len() == k_r {
                        break;
                    }
                    dl = self
                        .draft
                        .step(&[d], std::slice::from_mut(&mut dkv))?;
                }
                draft_time += t0.elapsed();
            }
            proposed += k_r;
            // one ragged spine call verifies every window row: feed
            // the pending token plus all k_r proposals, read back all
            // k_r + 1 new rows' logits
            let mut feed = Vec::with_capacity(k_r + 1);
            feed.push(*prefix.last().expect("prefix is never empty"));
            feed.extend_from_slice(&drafts);
            let t0 = Instant::now();
            let logits = self.target.model().forward_ragged(
                &feed,
                &[feed.len()],
                std::slice::from_mut(&mut tkv),
                false,
            )?;
            verify_time += t0.elapsed();
            let max_emit = remaining_new.min(ctx_room);
            let (emitted, accepted) = accept_window(
                &mut sampler,
                &logits,
                vocab,
                &drafts,
                eos,
                max_emit,
            );
            accepted_total += accepted;
            let hit_eos = emitted.last().copied().is_some_and(|t| {
                eos == Some(t)
            });
            out.extend_from_slice(&emitted);
            prefix.extend_from_slice(&emitted);
            if hit_eos || out.len() >= max_new || prefix.len() > dims.seq_len
            {
                break;
            }
            // roll rejected rows back off both caches: the valid
            // cached prefix is everything but the pending token
            let keep = prefix.len() - 1;
            tkv.truncate(keep)?;
            dkv.truncate(keep)?;
        }
        Ok(SpecOutput {
            tokens: out,
            proposed,
            accepted: accepted_total,
            rounds,
            draft_time,
            verify_time,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::Params;
    use crate::runtime::artifacts::ModelDims;
    use crate::runtime::qconfig::{PerLayerQConfig, QConfig};
    use crate::serve::cache::OperandCache;
    use crate::serve::decode::generate_reforward;

    fn tiny() -> (ModelDims, Params) {
        let dims = ModelDims {
            vocab: 48,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            d_ff: 64,
            seq_len: 40,
        };
        let params = Params::init_surrogate(&dims, 77);
        (dims, params)
    }

    fn model(
        dims: &ModelDims,
        params: &Params,
        cfg: QConfig,
        cache: &OperandCache,
    ) -> Arc<PackedModel> {
        Arc::new(
            PackedModel::build(
                dims,
                params,
                &PerLayerQConfig::uniform(cfg),
                8,
                cache,
            )
            .unwrap(),
        )
    }

    #[test]
    fn accept_window_matches_and_stops_exactly() {
        let mut s = Sampler::new(&Sampling::Greedy).unwrap();
        // rows argmax: 1, 0, 2 — drafts [1, 0]: both accepted + bonus
        let logits = vec![
            0.0, 9.0, 0.0, //
            9.0, 0.0, 0.0, //
            0.0, 0.0, 9.0, //
        ];
        let (em, acc) =
            accept_window(&mut s, &logits, 3, &[1, 0], None, 10);
        assert_eq!(em, vec![1, 0, 2]);
        assert_eq!(acc, 2);
        // first mismatch replaces and stops
        let (em, acc) =
            accept_window(&mut s, &logits, 3, &[2, 0], None, 10);
        assert_eq!(em, vec![1]);
        assert_eq!(acc, 0);
        // eos stops emission mid-window even on a match
        let (em, acc) =
            accept_window(&mut s, &logits, 3, &[1, 0], Some(1), 10);
        assert_eq!(em, vec![1]);
        assert_eq!(acc, 0, "eos token is emitted but ends the stream");
        // max_emit caps the window (and the RNG draws with it)
        let (em, acc) =
            accept_window(&mut s, &logits, 3, &[1, 0], None, 2);
        assert_eq!(em, vec![1, 0]);
        assert_eq!(acc, 2);
    }

    #[test]
    fn spec_stream_equals_the_reforward_oracle() {
        let (dims, params) = tiny();
        let cache = OperandCache::new(64);
        let target =
            model(&dims, &params, QConfig::baseline(), &cache);
        let draft =
            model(&dims, &params, QConfig::fp4("ue5m3").unwrap(), &cache);
        let prompt: Vec<i32> = vec![5, 11, 2, 33, 7];
        for k in [1usize, 3, 6] {
            let eng =
                SpecDecodeEngine::new(target.clone(), draft.clone(), k)
                    .unwrap();
            for sampling in [
                Sampling::Greedy,
                Sampling::Temperature { temp: 0.9, seed: 0xC0FFEE },
            ] {
                let want = generate_reforward(
                    &target, &prompt, 16, None, &sampling,
                )
                .unwrap();
                let got =
                    eng.generate(&prompt, 16, None, &sampling).unwrap();
                assert_eq!(got.tokens, want, "k={k} {sampling:?}");
                assert!(got.proposed >= got.accepted);
                assert!(got.rounds >= 1);
            }
        }
    }

    #[test]
    fn draft_equals_target_accepts_every_greedy_proposal() {
        let (dims, params) = tiny();
        let cache = OperandCache::new(64);
        let target =
            model(&dims, &params, QConfig::fp4("ue5m3").unwrap(), &cache);
        let eng =
            SpecDecodeEngine::new(target.clone(), target.clone(), 4)
                .unwrap();
        let out = eng
            .generate(&[3, 1, 4, 1, 5], 20, None, &Sampling::Greedy)
            .unwrap();
        assert_eq!(out.tokens.len(), 20);
        assert_eq!(
            out.accepted, out.proposed,
            "identical configs must agree on every greedy proposal"
        );
        assert!((out.acceptance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn engine_validates_shape_and_depth() {
        let (dims, params) = tiny();
        let cache = OperandCache::new(64);
        let target = model(&dims, &params, QConfig::baseline(), &cache);
        let mut other = dims;
        other.seq_len = 8;
        let small_params = Params::init_surrogate(&other, 77);
        let small =
            model(&other, &small_params, QConfig::baseline(), &cache);
        assert!(SpecDecodeEngine::new(target.clone(), small, 2).is_err());
        assert!(
            SpecDecodeEngine::new(target.clone(), target.clone(), 0)
                .is_err()
        );
    }
}
