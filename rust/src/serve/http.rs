//! Streaming HTTP front-end over the [`Scheduler`] (see
//! [`super::net`] for the wire layer; this module is the glue).
//!
//! # Threading model
//!
//! The scheduler is **not** shared: one dedicated loop thread owns it
//! outright and everything else talks to it over an mpsc [`Cmd`]
//! channel. The loop blocks on `recv` while the scheduler is idle
//! (zero CPU between requests), and while work is in flight it drains
//! pending commands with `try_recv` between [`Scheduler::step`] calls
//! — so admission, cancellation, and stats stay responsive at exactly
//! step granularity without any locking around model state. The loop
//! exits once the command channel is closed *and* the scheduler is
//! idle, so shutdown never abandons admitted work.
//!
//! Each accepted connection gets its own thread (requests are
//! long-lived token streams; a thread per stream is the simplest
//! correct thing at our scale). Connections are **persistent** per
//! HTTP/1.1 ([`net::Request::keep_alive`]): the connection loop
//! serves requests back-to-back on one socket until the client sends
//! `Connection: close` (or is HTTP/1.0 without `keep-alive`), the
//! per-connection request cap [`MAX_REQUESTS_PER_CONN`] is reached —
//! the last allowed response advertises `Connection: close` — an idle
//! gap exceeds [`KEEP_ALIVE_IDLE`], or a request fails to parse
//! (best-effort `400`, then close). Every response's `Connection`
//! header states what the loop will actually do next.
//!
//! # Determinism
//!
//! The front-end inherits the scheduler's contract: token streams are
//! a pure function of `(weights, qconfig, prompt, sampling)`, so HTTP
//! concurrency, arrival interleaving, and priority classes cannot
//! change any stream — `rust/tests/http.rs` pins served streams
//! against the [`super::decode::generate_reforward`] oracle.
//!
//! # Cancellation
//!
//! A client disconnect mid-stream surfaces as a failed chunk write;
//! the connection thread then sends [`Cmd::Cancel`] and drops its
//! event receiver (either alone suffices — the scheduler also cancels
//! on a hung-up sink). The scheduler frees the sequence's KV pages on
//! the spot, so a disconnected client's pages never linger.
//!
//! # API
//!
//! * `GET /healthz` — liveness: `{"ok": true}`.
//! * `GET /stats` — scheduler + KV pool counters ([`ServerStats`]).
//! * `POST /v1/completions` — body `{"prompt": [i32, ..], ..}`; see
//!   [`parse_completion`] for the accepted fields. With
//!   `"stream": true` the response is a `text/event-stream` of
//!   `data: {"token": N}` events, terminated by `data: {"done": ..}`;
//!   otherwise one JSON object after the request finishes.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{anyhow, Context};

use crate::util::json::{self, Json};

use super::net;
use super::scheduler::{
    DecodeRequest, DecodeResult, Priority, Scheduler, StreamEvent,
};
use super::Sampling;

/// Most requests served on one persistent connection before the
/// server closes it (resource hygiene: a chatty client re-handshakes
/// occasionally instead of pinning a thread forever). The capping
/// response advertises `Connection: close`.
pub const MAX_REQUESTS_PER_CONN: usize = 100;

/// How long a persistent connection may sit idle between requests
/// before the server closes it.
pub const KEEP_ALIVE_IDLE: Duration = Duration::from_secs(5);

/// What connection threads ask of the scheduler loop.
enum Cmd {
    /// Admit a request; `reply` carries the validation verdict
    /// ([`Scheduler::submit_streaming`]'s result) back to the
    /// connection before any token flows.
    Submit {
        req: DecodeRequest,
        sink: mpsc::Sender<StreamEvent>,
        reply: mpsc::Sender<crate::Result<()>>,
    },
    /// Drop a request wherever it sits (client disconnected).
    Cancel { id: u64 },
    /// Snapshot the counters.
    Stats { reply: mpsc::Sender<ServerStats> },
}

/// Scheduler + KV pool counters, as served by `GET /stats`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerStats {
    /// Requests waiting for admission.
    pub pending: usize,
    /// Requests decoding right now.
    pub active: usize,
    /// Requests evicted and awaiting re-admission.
    pub preempted: usize,
    /// Lifetime eviction count.
    pub preemptions: u64,
    /// Lifetime cancellation count.
    pub cancellations: u64,
    /// KV pool bytes currently allocated (0 without a paged pool).
    pub kv_used_bytes: usize,
    /// KV pool high-water mark.
    pub kv_peak_bytes: usize,
    /// Full pages deduplicated by prefix sharing.
    pub kv_dedup_hits: u64,
    /// Extra bytes an unshared pool would hold right now.
    pub kv_shared_bytes: usize,
}

impl ServerStats {
    fn to_json(self) -> Json {
        json::obj(vec![
            ("pending", json::num(self.pending as f64)),
            ("active", json::num(self.active as f64)),
            ("preempted", json::num(self.preempted as f64)),
            ("preemptions", json::num(self.preemptions as f64)),
            ("cancellations", json::num(self.cancellations as f64)),
            ("kv_used_bytes", json::num(self.kv_used_bytes as f64)),
            ("kv_peak_bytes", json::num(self.kv_peak_bytes as f64)),
            ("kv_dedup_hits", json::num(self.kv_dedup_hits as f64)),
            ("kv_shared_bytes", json::num(self.kv_shared_bytes as f64)),
        ])
    }
}

fn snapshot(sched: &Scheduler) -> ServerStats {
    let pool = sched.pool().map(|p| p.stats());
    ServerStats {
        pending: sched.pending(),
        active: sched.active(),
        preempted: sched.preempted(),
        preemptions: sched.preemptions(),
        cancellations: sched.cancellations(),
        kv_used_bytes: pool.map_or(0, |p| p.used_bytes),
        kv_peak_bytes: pool.map_or(0, |p| p.peak_bytes),
        kv_dedup_hits: pool.map_or(0, |p| p.dedup_hits),
        kv_shared_bytes: pool.map_or(0, |p| p.shared_bytes),
    }
}

/// The scheduler-owning loop (see module docs for the idle/busy
/// protocol). Step errors drop the in-flight set (the scheduler's
/// own contract) but the loop keeps serving — submit-time validation
/// makes forward errors unreachable for admitted requests.
fn scheduler_loop(mut sched: Scheduler, rx: mpsc::Receiver<Cmd>) {
    let mut open = true;
    loop {
        if sched.is_idle() {
            if !open {
                return;
            }
            match rx.recv() {
                Ok(cmd) => apply(&mut sched, cmd),
                Err(_) => return,
            }
        }
        while open {
            match rx.try_recv() {
                Ok(cmd) => apply(&mut sched, cmd),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => open = false,
            }
        }
        if !sched.is_idle() {
            let _ = sched.step();
        }
    }
}

fn apply(sched: &mut Scheduler, cmd: Cmd) {
    match cmd {
        Cmd::Submit { req, sink, reply } => {
            let _ = reply.send(sched.submit_streaming(req, sink));
        }
        Cmd::Cancel { id } => {
            sched.cancel(id);
        }
        Cmd::Stats { reply } => {
            let _ = reply.send(snapshot(sched));
        }
    }
}

/// Decode a `POST /v1/completions` body. Accepted fields:
///
/// * `prompt` (required): token id array.
/// * `max_new_tokens` (default 16), `eos` (default none).
/// * `temperature` + `seed` → [`Sampling::Temperature`]; omitting
///   `temperature` means greedy. `seed` defaults to 0.
/// * `priority`: `"interactive"` (default) or `"batch"`.
/// * `stream`: `true` for SSE token streaming (default `false`).
///
/// The request id is server-assigned — bodies cannot pick one.
fn parse_completion(
    body: &[u8],
    id: u64,
) -> crate::Result<(DecodeRequest, bool)> {
    let text = std::str::from_utf8(body).context("body is not UTF-8")?;
    let j = Json::parse(text).context("body is not JSON")?;
    let prompt: Vec<i32> = j
        .get("prompt")?
        .as_f64_vec()
        .context("prompt must be a token id array")?
        .into_iter()
        .map(|v| v as i32)
        .collect();
    let max_new_tokens = match j.opt("max_new_tokens") {
        Some(v) => v.as_usize().context("max_new_tokens")?,
        None => 16,
    };
    let eos = match j.opt("eos") {
        None | Some(Json::Null) => None,
        Some(v) => Some(v.as_i64().context("eos")? as i32),
    };
    let sampling = match j.opt("temperature") {
        Some(t) => Sampling::Temperature {
            temp: t.as_f64().context("temperature")?,
            seed: match j.opt("seed") {
                Some(v) => v.as_f64().context("seed")? as u64,
                None => 0,
            },
        },
        None => Sampling::Greedy,
    };
    let priority = match j.opt("priority") {
        Some(p) => {
            let name = p.as_str().context("priority")?;
            Priority::parse(name).ok_or_else(|| {
                anyhow!(
                    "unknown priority {name:?} (expected \
                     \"interactive\" or \"batch\")"
                )
            })?
        }
        None => Priority::Interactive,
    };
    let stream = match j.opt("stream") {
        Some(v) => v.as_bool().context("stream")?,
        None => false,
    };
    Ok((
        DecodeRequest { id, prompt, max_new_tokens, eos, sampling, priority },
        stream,
    ))
}

/// A finished request as JSON (the non-stream response body, and the
/// `"done"` payload of the final SSE event).
fn result_json(r: &DecodeResult) -> Json {
    json::obj(vec![
        ("id", json::num(r.id as f64)),
        ("priority", json::s(r.priority.as_str())),
        ("prompt_len", json::num(r.prompt_len as f64)),
        (
            "tokens",
            json::arr(r.tokens.iter().map(|&t| json::num(t as f64))),
        ),
        ("finish", json::s(r.finish.as_str())),
        ("queue_wait_ms", json::num(r.queue_wait.as_secs_f64() * 1e3)),
        ("ttft_ms", json::num(r.ttft.as_secs_f64() * 1e3)),
        (
            "itl_ms",
            json::f64s(
                &r.itl
                    .iter()
                    .map(|d| d.as_secs_f64() * 1e3)
                    .collect::<Vec<_>>(),
            ),
        ),
    ])
}

fn reason_for(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Error",
    }
}

fn write_error<W: std::io::Write>(
    w: &mut W,
    status: u16,
    msg: &str,
    keep_alive: bool,
) -> crate::Result<()> {
    let body = json::obj(vec![("error", json::s(msg))]).to_string();
    net::write_response(
        w,
        status,
        reason_for(status),
        "application/json",
        body.as_bytes(),
        keep_alive,
    )
}

/// Serve `POST /v1/completions` on an established connection.
fn completions(
    req: &net::Request,
    out: &mut &TcpStream,
    cmd_tx: &mpsc::Sender<Cmd>,
    id: u64,
    keep_alive: bool,
) -> crate::Result<()> {
    let (dreq, stream_mode) = match parse_completion(&req.body, id) {
        Ok(parsed) => parsed,
        Err(e) => {
            return write_error(out, 400, &format!("{e:#}"), keep_alive)
        }
    };
    let (sink_tx, sink_rx) = mpsc::channel();
    let (reply_tx, reply_rx) = mpsc::channel();
    let submitted = cmd_tx
        .send(Cmd::Submit { req: dreq, sink: sink_tx, reply: reply_tx })
        .is_ok();
    if !submitted {
        return write_error(
            out,
            503,
            "server is shutting down",
            keep_alive,
        );
    }
    match reply_rx.recv() {
        Ok(Ok(())) => {}
        Ok(Err(e)) => {
            return write_error(out, 400, &format!("{e:#}"), keep_alive)
        }
        Err(_) => {
            return write_error(
                out,
                503,
                "scheduler unavailable",
                keep_alive,
            )
        }
    }
    if stream_mode {
        let mut cw = net::ChunkWriter::start(
            &mut *out,
            200,
            "OK",
            "text/event-stream",
            keep_alive,
        )?;
        for ev in sink_rx.iter() {
            match ev {
                StreamEvent::Token(t) => {
                    let data = format!("data: {{\"token\":{t}}}\n\n");
                    if cw.chunk(data.as_bytes()).is_err() {
                        // Client hung up: reclaim the sequence's KV
                        // pages now (the dropped sink_rx would also
                        // get there, one step later).
                        let _ = cmd_tx.send(Cmd::Cancel { id });
                        return Ok(());
                    }
                }
                StreamEvent::Done(r) => {
                    let done = result_json(&r).to_string();
                    let data = format!("data: {{\"done\":{done}}}\n\n");
                    let _ = cw.chunk(data.as_bytes());
                    return cw.end();
                }
            }
        }
        // Sink closed without Done: the scheduler dropped the request
        // (step error). Terminate the stream so the client unblocks.
        let _ = cw.chunk(b"data: {\"error\":\"request dropped\"}\n\n");
        cw.end()
    } else {
        for ev in sink_rx.iter() {
            if let StreamEvent::Done(r) = ev {
                let body = result_json(&r).to_string();
                return net::write_response(
                    out,
                    200,
                    "OK",
                    "application/json",
                    body.as_bytes(),
                    keep_alive,
                );
            }
        }
        write_error(out, 500, "request dropped", keep_alive)
    }
}

fn route(
    req: &net::Request,
    out: &mut &TcpStream,
    cmd_tx: &mpsc::Sender<Cmd>,
    ids: &AtomicU64,
    keep_alive: bool,
) -> crate::Result<()> {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => net::write_response(
            out,
            200,
            "OK",
            "application/json",
            b"{\"ok\":true}",
            keep_alive,
        ),
        ("GET", "/stats") => {
            let (tx, rx) = mpsc::channel();
            if cmd_tx.send(Cmd::Stats { reply: tx }).is_err() {
                return write_error(
                    out,
                    503,
                    "server is shutting down",
                    keep_alive,
                );
            }
            match rx.recv() {
                Ok(stats) => {
                    let body = stats.to_json().to_string();
                    net::write_response(
                        out,
                        200,
                        "OK",
                        "application/json",
                        body.as_bytes(),
                        keep_alive,
                    )
                }
                Err(_) => write_error(
                    out,
                    503,
                    "scheduler unavailable",
                    keep_alive,
                ),
            }
        }
        ("POST", "/v1/completions") => {
            let id = ids.fetch_add(1, Ordering::Relaxed);
            completions(req, out, cmd_tx, id, keep_alive)
        }
        _ => write_error(out, 404, "no such route", keep_alive),
    }
}

/// One persistent connection (module docs): serve requests
/// back-to-back on the socket until the client's framing says close,
/// the request cap is reached, the idle timeout fires, or a request
/// fails to parse. Socket errors just end the connection — the peer
/// is gone.
fn handle_conn(
    stream: TcpStream,
    cmd_tx: mpsc::Sender<Cmd>,
    ids: Arc<AtomicU64>,
) {
    let Ok(read_half) = stream.try_clone() else { return };
    // bound the wait for the *next* request so an idle keep-alive
    // client cannot pin this thread (and block server shutdown)
    // forever; mid-request reads share the same bound
    let _ = read_half.set_read_timeout(Some(KEEP_ALIVE_IDLE));
    let mut reader = BufReader::new(read_half);
    let mut out = &stream;
    for served in 1..=MAX_REQUESTS_PER_CONN {
        let req = match net::read_request(&mut reader) {
            Ok(Some(req)) => req,
            // clean EOF: the peer is done with the connection
            Ok(None) => return,
            Err(e) => {
                // an idle timeout is a normal keep-alive close, not a
                // protocol error — only garbage earns a 400
                let timed_out =
                    e.downcast_ref::<std::io::Error>().is_some_and(|io| {
                        matches!(
                            io.kind(),
                            std::io::ErrorKind::WouldBlock
                                | std::io::ErrorKind::TimedOut
                        )
                    });
                if !timed_out {
                    let _ = write_error(
                        &mut out,
                        400,
                        &format!("{e:#}"),
                        false,
                    );
                }
                return;
            }
        };
        let keep_alive =
            req.keep_alive() && served < MAX_REQUESTS_PER_CONN;
        if route(&req, &mut out, &cmd_tx, &ids, keep_alive).is_err() {
            return;
        }
        if !keep_alive {
            return;
        }
    }
}

/// The serving edge: a TCP listener, per-connection threads, and the
/// scheduler loop, bundled behind one handle. Dropping the handle
/// shuts everything down in order (stop accepting → finish open
/// connections → close the command channel → drain the scheduler).
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    cmd_tx: Option<mpsc::Sender<Cmd>>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    sched_loop: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (use port 0 for an OS-assigned port) and start
    /// serving `sched`. The scheduler must be idle-or-fresh; it is
    /// consumed — the server's loop thread owns it from here on.
    pub fn start(sched: Scheduler, addr: &str) -> crate::Result<HttpServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr().context("local_addr")?;
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let sched_loop = thread::Builder::new()
            .name("http-sched".into())
            .spawn(move || scheduler_loop(sched, cmd_rx))
            .context("spawning scheduler loop")?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let ids = Arc::new(AtomicU64::new(1));
        let accept = {
            let stop = stop.clone();
            let conns = conns.clone();
            let cmd_tx = cmd_tx.clone();
            thread::Builder::new()
                .name("http-accept".into())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = stream else { continue };
                        let tx = cmd_tx.clone();
                        let ids = ids.clone();
                        let handle = thread::Builder::new()
                            .name("http-conn".into())
                            .spawn(move || handle_conn(stream, tx, ids));
                        if let Ok(h) = handle {
                            conns.lock().unwrap().push(h);
                        }
                    }
                })
                .context("spawning accept loop")?
        };
        Ok(HttpServer {
            addr: local,
            stop,
            cmd_tx: Some(cmd_tx),
            accept: Some(accept),
            conns,
            sched_loop: Some(sched_loop),
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Orderly shutdown; also runs on drop. Open streams finish —
    /// the scheduler loop drains admitted work before exiting.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.accept.is_none() && self.cmd_tx.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // Wake the accept loop so it observes the stop flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        // Last sender gone → the scheduler loop drains and exits.
        self.cmd_tx = None;
        if let Some(h) = self.sched_loop.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completion_body_defaults_and_overrides() {
        let (req, stream) =
            parse_completion(br#"{"prompt": [1, 2, 3]}"#, 7).unwrap();
        assert_eq!(req.id, 7);
        assert_eq!(req.prompt, vec![1, 2, 3]);
        assert_eq!(req.max_new_tokens, 16);
        assert_eq!(req.eos, None);
        assert_eq!(req.sampling, Sampling::Greedy);
        assert_eq!(req.priority, Priority::Interactive);
        assert!(!stream);

        let body = br#"{"prompt": [4], "max_new_tokens": 3, "eos": 0,
                        "temperature": 0.5, "seed": 9,
                        "priority": "batch", "stream": true}"#;
        let (req, stream) = parse_completion(body, 8).unwrap();
        assert_eq!(req.max_new_tokens, 3);
        assert_eq!(req.eos, Some(0));
        assert_eq!(
            req.sampling,
            Sampling::Temperature { temp: 0.5, seed: 9 }
        );
        assert_eq!(req.priority, Priority::Batch);
        assert!(stream);

        // `"eos": null` means "no stop token", same as omitting it.
        let (req, _) =
            parse_completion(br#"{"prompt": [4], "eos": null}"#, 9).unwrap();
        assert_eq!(req.eos, None);
    }

    #[test]
    fn completion_body_rejects_malformed_input() {
        for body in [
            &b"not json"[..],
            br#"{"max_new_tokens": 4}"#,           // prompt missing
            br#"{"prompt": "abc"}"#,               // prompt not an array
            br#"{"prompt": [1], "priority": "x"}"#, // unknown class
            br#"{"prompt": [1], "stream": 3}"#,    // stream not a bool
        ] {
            assert!(parse_completion(body, 1).is_err(), "{body:?}");
        }
    }

    #[test]
    fn result_json_carries_tokens_and_timing() {
        use super::super::scheduler::FinishReason;
        use std::time::Duration;
        let r = DecodeResult {
            id: 3,
            prompt_len: 5,
            priority: Priority::Batch,
            tokens: vec![7, 8, 0],
            finish: FinishReason::Eos,
            queue_wait: Duration::from_millis(2),
            ttft: Duration::from_millis(10),
            itl: vec![Duration::from_millis(4); 2],
        };
        let j = result_json(&r);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("id").unwrap().as_usize().unwrap(), 3);
        assert_eq!(parsed.get("finish").unwrap().as_str().unwrap(), "eos");
        assert_eq!(
            parsed.get("priority").unwrap().as_str().unwrap(),
            "batch"
        );
        assert_eq!(
            parsed.get("tokens").unwrap().as_f64_vec().unwrap(),
            vec![7.0, 8.0, 0.0]
        );
        assert_eq!(parsed.get("itl_ms").unwrap().as_arr().unwrap().len(), 2);
        assert!(
            (parsed.get("ttft_ms").unwrap().as_f64().unwrap() - 10.0).abs()
                < 1e-9
        );
    }
}
