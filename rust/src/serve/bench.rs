//! The `microscale serve-bench` driver: synthetic request traffic over
//! the packed-domain serving stack, across the paper's format axis
//! ({FP4/UE4M3, FP4/UE5M3, FP8, mixed-per-layer}) × batch sizes ×
//! tensor-parallel shard counts.
//!
//! Per config the driver (1) builds a [`PackedModel`] through the
//! shared operand cache, (2) gates on bit-exactness against the scalar
//! fake-quant [`reference_forward`] — nothing is timed unless the
//! outputs match bit for bit, (3) measures the single-request **serial**
//! baseline (1 worker, batch 1, single-threaded GEMM), then (4) drives
//! batched traffic through a threaded [`ServeEngine`] per batch size,
//! and (5) re-runs the largest batch size per shard count on a
//! **controlled** sharded engine — one worker, inner GEMM pinned
//! serial, each sharded forward gated bit-exact against the unsharded
//! bits — so every concurrent core in that section comes from
//! [`PackedModel::build_sharded`]'s shard fan-out and the axis
//! isolates shard scaling. Results land in machine-readable
//! **`BENCH_serve.json`** (field map in EXPERIMENTS.md §Perf); the
//! acceptance lines check the batch-32 engine at ≥ 3× the serial
//! baseline and shards=2 at ≥ 1.6× shards=1 (full shapes only — smoke
//! runs record `pass: null`).
//!
//! Shared by the CLI subcommand and `cargo bench --bench serve_bench`.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Context;

use super::batcher::BatcherConfig;
use super::cache::operand_cache;
use super::engine::{EngineConfig, ServeEngine};
use super::packed_model::{reference_forward, PackedModel};
use crate::dist::Pcg64;
use crate::model::weights::Params;
use crate::quant::gemm::PackedGemm;
use crate::runtime::artifacts::ModelDims;
use crate::runtime::qconfig::{PerLayerQConfig, QConfig};
use crate::util::json::{self, Json};
use crate::util::par;

/// Driver options (CLI flags map onto these).
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// CI-sized run: tiny model, one small batch size, `pass: null`.
    pub smoke: bool,
    /// Report path (`BENCH_serve.json` in the working directory).
    pub out: PathBuf,
    /// Engine worker threads for the batched runs.
    pub workers: usize,
    /// Micro-batch sizes to drive.
    pub batch_sizes: Vec<usize>,
    /// Full batches of traffic per (config, batch size) point.
    pub rounds: usize,
    /// Requests in the serial baseline measurement.
    pub serial_requests: usize,
    /// Tensor-parallel shard counts to drive at the largest batch size.
    pub shard_counts: Vec<usize>,
    /// Override the config axis (label, per-layer config).
    pub qconfigs: Option<Vec<(String, PerLayerQConfig)>>,
    /// Override the global block size (tuned configs carry their own
    /// via `--qconfig-file`; per-layer `@bsN` overrides still win).
    pub block_size: Option<usize>,
}

impl BenchOpts {
    pub fn new(smoke: bool) -> BenchOpts {
        BenchOpts {
            smoke,
            out: PathBuf::from("BENCH_serve.json"),
            workers: par::max_threads().min(4),
            batch_sizes: if smoke { vec![4] } else { vec![8, 32] },
            rounds: if smoke { 1 } else { 2 },
            serial_requests: if smoke { 2 } else { 6 },
            shard_counts: if smoke { vec![1, 2] } else { vec![1, 2, 4] },
            qconfigs: None,
            block_size: None,
        }
    }
}

/// Full runs use the repo's tiny preset (`model.py::ModelConfig`);
/// smoke shrinks every axis so CI proves the path in seconds.
fn bench_dims(smoke: bool) -> ModelDims {
    if smoke {
        ModelDims {
            vocab: 64,
            d_model: 64,
            n_heads: 2,
            n_layers: 2,
            d_ff: 128,
            seq_len: 16,
        }
    } else {
        ModelDims {
            vocab: 256,
            d_model: 128,
            n_heads: 4,
            n_layers: 4,
            d_ff: 512,
            seq_len: 128,
        }
    }
}

/// The default config axis: the paper's FP4 scale-format pair, FP8, and
/// a mixed per-layer assignment (first/last layers at FP8, the bulk at
/// FP4/UE5M3 — the *Scaling Laws For Mixed Quantization* shape).
/// Shared with [`super::decode_bench`] so the two reports cover the
/// same format axis.
pub(crate) fn default_configs(
    dims: &ModelDims,
) -> crate::Result<Vec<(String, PerLayerQConfig)>> {
    let fp8 = QConfig::named("fp8_e4m3", "ue4m3", false)?;
    let fp8_53 = QConfig::named("fp8_e4m3", "ue5m3", false)?;
    let mixed = PerLayerQConfig::uniform(QConfig::fp4("ue5m3")?)
        .with_override(0, fp8_53)
        .with_override(dims.n_layers.saturating_sub(1), fp8_53);
    Ok(vec![
        (
            "fp4_ue4m3".to_string(),
            PerLayerQConfig::uniform(QConfig::fp4("ue4m3")?),
        ),
        (
            "fp4_ue5m3".to_string(),
            PerLayerQConfig::uniform(QConfig::fp4("ue5m3")?),
        ),
        ("fp8".to_string(), PerLayerQConfig::uniform(fp8)),
        ("mixed".to_string(), mixed),
    ])
}

fn random_tokens(rng: &mut Pcg64, dims: &ModelDims, batch: usize) -> Vec<i32> {
    (0..batch * dims.seq_len)
        .map(|_| (rng.next_u64() % dims.vocab as u64) as i32)
        .collect()
}

/// Run the bench and write the report; returns the report JSON.
pub fn run(opts: &BenchOpts) -> crate::Result<Json> {
    let dims = bench_dims(opts.smoke);
    let block_size = opts
        .block_size
        .unwrap_or(if opts.smoke { 16 } else { 32 });
    let params = Params::init_surrogate(&dims, 2026);
    let configs = match &opts.qconfigs {
        Some(c) => c.clone(),
        None => default_configs(&dims)?,
    };
    let largest_bs = opts.batch_sizes.iter().copied().max().unwrap_or(1);
    let mut rng = Pcg64::new(0x5E21);

    println!(
        "== serve-bench ({}) : {} layers, d_model {}, d_ff {}, seq {}, \
         bs{block_size} blocks, {} engine workers ==",
        if opts.smoke { "smoke" } else { "full" },
        dims.n_layers,
        dims.d_model,
        dims.d_ff,
        dims.seq_len,
        opts.workers,
    );

    let mut config_entries: Vec<(String, Json)> = Vec::new();
    let mut min_speedup = f64::INFINITY;
    let mut min_shard2 = f64::INFINITY;
    for (label, qcfg) in &configs {
        let t_build = Instant::now();
        let model = Arc::new(PackedModel::build(
            &dims,
            &params,
            qcfg,
            block_size,
            operand_cache(),
        )?);
        let build_ms = t_build.elapsed().as_secs_f64() * 1e3;
        let paths = model.path_summary();

        // correctness gate: nothing is timed unless the packed forward
        // is bit-identical to the scalar fake-quant reference
        let gate_batch = 2usize;
        let toks = random_tokens(&mut rng, &dims, gate_batch);
        let got = model.forward(&toks, gate_batch, dims.seq_len)?;
        let want = reference_forward(
            &params,
            &dims,
            qcfg,
            block_size,
            &toks,
            gate_batch,
            dims.seq_len,
        )?;
        let ok = got.len() == want.len()
            && got
                .iter()
                .zip(&want)
                .all(|(a, b)| a.to_bits() == b.to_bits());
        anyhow::ensure!(
            ok,
            "{label}: packed forward diverges from the scalar reference — \
             refusing to time"
        );
        println!(
            "\n-- {label} ({}) : {} packed / {} reference / {} exact \
             linears, build {build_ms:.1} ms, bit-exact vs reference OK",
            qcfg.id(),
            paths.packed,
            paths.reference,
            paths.exact,
        );

        // serial baseline: one request at a time, one worker, GEMM
        // pinned single-threaded (operands come from the cache, so this
        // second build re-encodes nothing)
        let serial_model = Arc::new(
            PackedModel::build(&dims, &params, qcfg, block_size, operand_cache())?
                .with_gemm(PackedGemm::serial()),
        );
        let serial_engine = ServeEngine::start(
            serial_model,
            EngineConfig {
                workers: 1,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_wait: Duration::from_micros(50),
                },
            },
        )?;
        let t0 = Instant::now();
        for _ in 0..opts.serial_requests {
            let toks = random_tokens(&mut rng, &dims, 1);
            serial_engine.infer(toks)?;
        }
        let serial_secs = t0.elapsed().as_secs_f64();
        serial_engine.shutdown();
        let serial_req_s = opts.serial_requests as f64 / serial_secs.max(1e-9);
        println!(
            "   serial baseline: {serial_req_s:.2} req/s \
             ({:.1} ms/request)",
            1e3 * serial_secs / opts.serial_requests as f64
        );

        let mut batch_entries: Vec<(String, Json)> = Vec::new();
        let mut cfg_speedup = f64::NAN;
        for &bs in &opts.batch_sizes {
            let engine = ServeEngine::start(
                model.clone(),
                EngineConfig {
                    workers: opts.workers,
                    batcher: BatcherConfig {
                        max_batch: bs,
                        max_wait: Duration::from_millis(2),
                    },
                },
            )?;
            let n_req = bs * opts.rounds;
            let t0 = Instant::now();
            let mut handles = Vec::with_capacity(n_req);
            for _ in 0..n_req {
                handles.push(engine.submit(random_tokens(&mut rng, &dims, 1))?);
            }
            for h in handles {
                h.wait()?;
            }
            let secs = t0.elapsed().as_secs_f64();
            let stats = engine.shutdown();
            let req_s = n_req as f64 / secs.max(1e-9);
            let tok_s = req_s * dims.seq_len as f64;
            let speedup = req_s / serial_req_s;
            if bs == largest_bs {
                cfg_speedup = speedup;
            }
            println!(
                "   bs{bs:<3}: {req_s:7.2} req/s  {tok_s:9.0} tok/s  \
                 p50 {:7.1} ms  p95 {:7.1} ms  p99 {:7.1} ms  \
                 mean batch {:.1}  ({speedup:.2}x vs serial)",
                stats.p50_ms, stats.p95_ms, stats.p99_ms, stats.mean_batch,
            );
            batch_entries.push((
                format!("bs{bs}"),
                json::obj(vec![
                    ("requests", json::num(n_req as f64)),
                    ("req_per_s", json::num(req_s)),
                    ("tok_per_s", json::num(tok_s)),
                    ("p50_ms", json::num(stats.p50_ms)),
                    ("p95_ms", json::num(stats.p95_ms)),
                    ("p99_ms", json::num(stats.p99_ms)),
                    ("mean_batch", json::num(stats.mean_batch)),
                    ("speedup_vs_serial", json::num(speedup)),
                ]),
            ));
        }
        if cfg_speedup.is_finite() {
            min_speedup = min_speedup.min(cfg_speedup);
        }

        // shard scaling: the largest batch size again, but one engine
        // worker and the inner GEMM pinned serial — every concurrent
        // core in this section comes from tensor-parallel shard
        // fan-out, so the ratio isolates shard scaling from batching
        // and GEMM threading
        let mut shard_entries: Vec<(String, Json)> = Vec::new();
        let mut shards1_req_s = f64::NAN;
        let mut cfg_shard2 = f64::NAN;
        for &shards in &opts.shard_counts {
            let smodel = Arc::new(
                PackedModel::build_sharded(
                    &dims,
                    &params,
                    qcfg,
                    block_size,
                    operand_cache(),
                    shards,
                )?
                .with_gemm(PackedGemm::serial()),
            );
            // bit-exactness gate: sharded logits must equal the
            // reference-checked unsharded bits before anything is timed
            let sharded = smodel.forward(&toks, gate_batch, dims.seq_len)?;
            anyhow::ensure!(
                sharded.len() == got.len()
                    && sharded
                        .iter()
                        .zip(&got)
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                "{label}: shards={shards} forward diverges from shards=1 \
                 — refusing to time"
            );
            let engine = ServeEngine::start(
                smodel,
                EngineConfig {
                    workers: 1,
                    batcher: BatcherConfig {
                        max_batch: largest_bs,
                        max_wait: Duration::from_millis(2),
                    },
                },
            )?;
            let n_req = largest_bs * opts.rounds;
            let t0 = Instant::now();
            let mut handles = Vec::with_capacity(n_req);
            for _ in 0..n_req {
                handles
                    .push(engine.submit(random_tokens(&mut rng, &dims, 1))?);
            }
            for h in handles {
                h.wait()?;
            }
            let secs = t0.elapsed().as_secs_f64();
            engine.shutdown();
            let req_s = n_req as f64 / secs.max(1e-9);
            if shards == 1 {
                shards1_req_s = req_s;
            }
            let speedup = req_s / shards1_req_s;
            if shards == 2 {
                cfg_shard2 = speedup;
            }
            println!(
                "   shards={shards}: {req_s:7.2} req/s at bs{largest_bs} \
                 ({speedup:.2}x vs 1 shard, bit-exact)"
            );
            shard_entries.push((
                format!("s{shards}"),
                json::obj(vec![
                    ("shards", json::num(shards as f64)),
                    ("requests", json::num(n_req as f64)),
                    ("req_per_s", json::num(req_s)),
                    ("tok_per_s", json::num(req_s * dims.seq_len as f64)),
                    ("bit_exact", Json::Bool(true)),
                    (
                        "speedup_vs_1shard",
                        if speedup.is_finite() {
                            json::num(speedup)
                        } else {
                            Json::Null
                        },
                    ),
                ]),
            ));
        }
        if cfg_shard2.is_finite() {
            min_shard2 = min_shard2.min(cfg_shard2);
        }

        config_entries.push((
            label.clone(),
            json::obj(vec![
                ("qconfig", json::s(&qcfg.id())),
                ("bit_exact", Json::Bool(true)),
                ("build_ms", json::num(build_ms)),
                (
                    "linear_paths",
                    json::obj(vec![
                        ("packed", json::num(paths.packed as f64)),
                        ("reference", json::num(paths.reference as f64)),
                        ("exact", json::num(paths.exact as f64)),
                    ]),
                ),
                (
                    "packed_weight_bytes",
                    json::num(model.packed_weight_bytes() as f64),
                ),
                ("serial_req_per_s", json::num(serial_req_s)),
                ("batch", json::obj_owned(batch_entries)),
                ("shards", json::obj_owned(shard_entries)),
            ]),
        ));
    }

    let batch_pass = min_speedup.is_finite() && min_speedup >= 3.0;
    // vacuous when the shard axis omits shards=2 (explicit --shards)
    let shard_pass = !min_shard2.is_finite() || min_shard2 >= 1.6;
    let pass = batch_pass && shard_pass;
    println!(
        "\n   acceptance target (engine >= 3.00x serial at bs{largest_bs}): {}",
        if opts.smoke {
            "n/a (smoke shapes)".to_string()
        } else if batch_pass {
            format!("PASS (min {min_speedup:.2}x)")
        } else {
            format!("MISS (min {min_speedup:.2}x, host-dependent)")
        }
    );
    println!(
        "   shard target (shards=2 >= 1.60x shards=1 at bs{largest_bs}): {}",
        if opts.smoke {
            "n/a (smoke shapes)".to_string()
        } else if !min_shard2.is_finite() {
            "n/a (no shards=2 point)".to_string()
        } else if min_shard2 >= 1.6 {
            format!("PASS (min {min_shard2:.2}x)")
        } else {
            format!("MISS (min {min_shard2:.2}x, host-dependent)")
        }
    );
    let cache = operand_cache().stats();
    let report = json::obj(vec![
        ("bench", json::s("serve")),
        ("smoke", Json::Bool(opts.smoke)),
        (
            "model",
            json::obj(vec![
                ("vocab", json::num(dims.vocab as f64)),
                ("d_model", json::num(dims.d_model as f64)),
                ("n_heads", json::num(dims.n_heads as f64)),
                ("n_layers", json::num(dims.n_layers as f64)),
                ("d_ff", json::num(dims.d_ff as f64)),
                ("seq_len", json::num(dims.seq_len as f64)),
                ("block_size", json::num(block_size as f64)),
            ]),
        ),
        ("workers", json::num(opts.workers as f64)),
        // a full-length request's scratch KV residency during its
        // forward (f32 rows; see hw::memory) — the figure that makes
        // this report memory-comparable with BENCH_decode/BENCH_kv
        (
            "kv_bytes_per_seq",
            json::num(
                (crate::hw::memory::kv_exact_position_bytes(
                    dims.d_model,
                    dims.n_layers,
                ) * dims.seq_len) as f64,
            ),
        ),
        ("configs", json::obj_owned(config_entries)),
        (
            "operand_cache",
            json::obj(vec![
                ("hits", json::num(cache.hits as f64)),
                ("misses", json::num(cache.misses as f64)),
                ("evictions", json::num(cache.evictions as f64)),
                ("entries", json::num(cache.entries as f64)),
                ("resident_bytes", json::num(cache.resident_bytes as f64)),
            ]),
        ),
        ("target_speedup", json::num(3.0)),
        (
            "min_batch_speedup",
            if min_speedup.is_finite() {
                json::num(min_speedup)
            } else {
                Json::Null
            },
        ),
        (
            "shard_counts",
            json::arr(
                opts.shard_counts.iter().map(|&s| json::num(s as f64)),
            ),
        ),
        ("shard_target", json::num(1.6)),
        (
            "min_shard2_speedup",
            if min_shard2.is_finite() {
                json::num(min_shard2)
            } else {
                Json::Null
            },
        ),
        // the 3x target is defined on the full shapes only; smoke runs
        // record null so trajectory tooling can't misread tiny-shape
        // ratios as an acceptance verdict
        (
            "pass",
            if opts.smoke { Json::Null } else { Json::Bool(pass) },
        ),
    ]);
    std::fs::write(&opts.out, report.to_string())
        .with_context(|| format!("writing {}", opts.out.display()))?;
    println!("   wrote {}", opts.out.display());
    Ok(report)
}
