//! The `microscale decode-bench` driver: KV-cached autoregressive
//! generation under continuous batching, across the paper's format axis
//! ({FP4/UE4M3, FP4/UE5M3, FP8, mixed-per-layer}) × concurrent-sequence
//! counts × tensor-parallel shard counts (the shard axis re-runs the
//! largest concurrency on a [`PackedModel::build_sharded`] model,
//! gating each shard count's greedy stream bit-identical to shards=1
//! before timing) × optional speculation depths (`--spec 1,2,4`: each
//! config's model verifies an FP4/UE5M3 draft through
//! [`super::spec::SpecDecodeEngine`], stream-exact-gated per depth —
//! the dedicated grid sweep lives in `microscale spec-bench`).
//!
//! Per config the driver (1) builds a [`PackedModel`] through the
//! shared operand cache, (2) gates on the decode exactness contract —
//! a forced-token generation whose KV-cached step logits must be
//! bit-identical to [`reference_forward`] re-run on the full prefix at
//! **every** step, and whose scheduler stream must equal the cache-free
//! [`generate_reforward`] stream — nothing is timed otherwise, (3)
//! measures the **re-forward-per-token** baseline (full-prefix forward
//! per generated token, no KV cache), then (4) drives the
//! [`Scheduler`] at each concurrency level, recording tok/s,
//! time-to-first-token, and inter-token p50/p95. Results land in
//! machine-readable **`BENCH_decode.json`** (field map in
//! EXPERIMENTS.md §Perf); the acceptance line checks cached decode at
//! the largest concurrency against the baseline at ≥ 2× tok/s (full
//! shapes only — smoke runs record `pass: null`).
//!
//! Shared by the CLI subcommand and `cargo bench --bench decode_bench`.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Context;

use super::cache::operand_cache;
use super::decode::{generate_reforward, DecodeEngine, Sampling};
use super::packed_model::{reference_forward, PackedModel};
use super::scheduler::{DecodeRequest, Priority, Scheduler, SchedulerConfig};
use crate::dist::Pcg64;
use crate::model::weights::Params;
use crate::runtime::artifacts::ModelDims;
use crate::runtime::qconfig::PerLayerQConfig;
use crate::util::json::{self, Json};

/// Driver options (CLI flags map onto these).
#[derive(Debug, Clone)]
pub struct DecodeBenchOpts {
    /// CI-sized run: tiny model, one small concurrency, `pass: null`.
    pub smoke: bool,
    /// Report path (`BENCH_decode.json` in the working directory).
    pub out: PathBuf,
    /// Concurrent-sequence counts to drive.
    pub concurrency: Vec<usize>,
    /// Prompt tokens per request.
    pub prompt_len: usize,
    /// Generation budget per request.
    pub max_new: usize,
    /// Request rounds per concurrency point (`requests = c × rounds`).
    pub rounds: usize,
    /// Requests in the re-forward-per-token baseline measurement.
    pub baseline_requests: usize,
    /// Tensor-parallel shard counts to drive at the largest concurrency.
    pub shard_counts: Vec<usize>,
    /// Speculation depths to drive per config with an FP4/UE5M3 draft
    /// (`--spec 1,2,4`); empty leaves the speculative axis off.
    pub spec_ks: Vec<usize>,
    /// Override the config axis (label, per-layer config).
    pub qconfigs: Option<Vec<(String, PerLayerQConfig)>>,
    /// Override the global block size (tuned configs carry their own
    /// via `--qconfig-file`; per-layer `@bsN` overrides still win).
    pub block_size: Option<usize>,
}

impl DecodeBenchOpts {
    pub fn new(smoke: bool) -> DecodeBenchOpts {
        DecodeBenchOpts {
            smoke,
            out: PathBuf::from("BENCH_decode.json"),
            concurrency: if smoke { vec![2] } else { vec![1, 4, 8] },
            prompt_len: if smoke { 4 } else { 32 },
            max_new: if smoke { 6 } else { 32 },
            rounds: if smoke { 1 } else { 2 },
            baseline_requests: if smoke { 2 } else { 4 },
            shard_counts: vec![1, 2],
            spec_ks: Vec::new(),
            qconfigs: None,
            block_size: None,
        }
    }
}

/// Bench model shapes, shared with [`super::kv_bench`] so the decode
/// and KV reports stay comparable.
pub(crate) fn bench_dims(smoke: bool) -> ModelDims {
    if smoke {
        ModelDims {
            vocab: 64,
            d_model: 64,
            n_heads: 2,
            n_layers: 2,
            d_ff: 128,
            seq_len: 32,
        }
    } else {
        ModelDims {
            vocab: 256,
            d_model: 128,
            n_heads: 4,
            n_layers: 4,
            d_ff: 512,
            seq_len: 128,
        }
    }
}

fn prompt(rng: &mut Pcg64, dims: &ModelDims, len: usize) -> Vec<i32> {
    (0..len).map(|_| (rng.next_u64() % dims.vocab as u64) as i32).collect()
}

/// The bit-exactness gate: generate a short forced-token stream and
/// assert the KV-cached step logits equal the full-prefix scalar
/// reference bit for bit at every step, then assert the scheduler's
/// greedy stream equals the cache-free re-forward stream.
fn exactness_gate(
    label: &str,
    model: &Arc<PackedModel>,
    params: &Params,
    qcfg: &PerLayerQConfig,
    block_size: usize,
    rng: &mut Pcg64,
) -> crate::Result<()> {
    let dims = *model.dims();
    let engine = DecodeEngine::new(model.clone())?;
    let steps = 4usize.min(dims.seq_len.saturating_sub(4));
    let toks = prompt(rng, &dims, 4 + steps);
    let mut kv = engine.new_kv();
    let mut got = engine.prefill(&toks[..4], &mut kv)?;
    for t in 4..=4 + steps {
        // `got` holds the cached logits for the t-token prefix
        let prefix = &toks[..t];
        let want =
            reference_forward(params, &dims, qcfg, block_size, prefix, 1, t)?;
        let last = &want[(t - 1) * dims.vocab..t * dims.vocab];
        anyhow::ensure!(
            got.iter().zip(last).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{label}: cached step logits diverge from the full-prefix \
             reference at position {t} — refusing to time"
        );
        if t == 4 + steps {
            break;
        }
        got = engine.step(&[toks[t]], std::slice::from_mut(&mut kv))?;
    }
    // stream-level: scheduler output == cache-free re-forward stream
    let p = prompt(rng, &dims, 4);
    let max_new = 4usize;
    let want = generate_reforward(model, &p, max_new, None, &Sampling::Greedy)?;
    let mut sched = Scheduler::new(
        DecodeEngine::new(model.clone())?,
        SchedulerConfig::default(),
    );
    sched.submit(DecodeRequest {
        id: 0,
        prompt: p,
        max_new_tokens: max_new,
        eos: None,
        sampling: Sampling::Greedy,
        priority: Priority::Interactive,
    })?;
    let results = sched.run()?;
    let got = results.first().map(|r| r.tokens.as_slice());
    anyhow::ensure!(
        got == Some(want.as_slice()),
        "{label}: scheduler stream {got:?} != re-forward stream {want:?}"
    );
    Ok(())
}

/// Run the bench and write the report; returns the report JSON.
pub fn run(opts: &DecodeBenchOpts) -> crate::Result<Json> {
    let dims = bench_dims(opts.smoke);
    let block_size = opts
        .block_size
        .unwrap_or(if opts.smoke { 16 } else { 32 });
    anyhow::ensure!(
        opts.prompt_len >= 1 && opts.prompt_len < dims.seq_len,
        "prompt length {} leaves no room to generate (seq_len {})",
        opts.prompt_len,
        dims.seq_len
    );
    let params = Params::init_surrogate(&dims, 2026);
    anyhow::ensure!(
        params.max_positions()? == dims.seq_len,
        "pos table supports {} positions, dims.seq_len is {}",
        params.max_positions()?,
        dims.seq_len
    );
    let configs = match &opts.qconfigs {
        Some(c) => c.clone(),
        None => super::bench::default_configs(&dims)?,
    };
    let largest_c = opts.concurrency.iter().copied().max().unwrap_or(1);
    let mut rng = Pcg64::new(0xDEC0);

    println!(
        "== decode-bench ({}) : {} layers, d_model {}, d_ff {}, seq {}, \
         bs{block_size} blocks, prompt {}, {} new tokens/request ==",
        if opts.smoke { "smoke" } else { "full" },
        dims.n_layers,
        dims.d_model,
        dims.d_ff,
        dims.seq_len,
        opts.prompt_len,
        opts.max_new,
    );

    let mut config_entries: Vec<(String, Json)> = Vec::new();
    let mut min_speedup = f64::INFINITY;
    for (label, qcfg) in &configs {
        let t_build = Instant::now();
        let model = Arc::new(PackedModel::build(
            &dims,
            &params,
            qcfg,
            block_size,
            operand_cache(),
        )?);
        let build_ms = t_build.elapsed().as_secs_f64() * 1e3;
        exactness_gate(label, &model, &params, qcfg, block_size, &mut rng)?;
        println!(
            "\n-- {label} ({}) : build {build_ms:.1} ms, step-wise bit-exact \
             vs full-prefix reference OK",
            qcfg.id(),
        );

        // baseline: no KV cache, full-prefix forward per generated token
        let base_prompts: Vec<Vec<i32>> = (0..opts.baseline_requests)
            .map(|_| prompt(&mut rng, &dims, opts.prompt_len))
            .collect();
        let t0 = Instant::now();
        let mut base_tokens = 0usize;
        for p in &base_prompts {
            base_tokens +=
                generate_reforward(&model, p, opts.max_new, None, &Sampling::Greedy)?
                    .len();
        }
        let base_secs = t0.elapsed().as_secs_f64();
        let base_tok_s = base_tokens as f64 / base_secs.max(1e-9);
        println!(
            "   re-forward baseline: {base_tok_s:8.1} tok/s \
             ({base_tokens} tokens, {:.1} ms/token)",
            1e3 * base_secs / base_tokens.max(1) as f64
        );

        let mut conc_entries: Vec<(String, Json)> = Vec::new();
        let mut cfg_speedup = f64::NAN;
        for &c in &opts.concurrency {
            let n_req = c * opts.rounds;
            let mut sched = Scheduler::new(
                DecodeEngine::new(model.clone())?,
                SchedulerConfig {
                    max_active: c,
                    max_prefill_per_step: c,
                    ..SchedulerConfig::default()
                },
            );
            let t0 = Instant::now();
            for id in 0..n_req {
                sched.submit(DecodeRequest {
                    id: id as u64,
                    prompt: prompt(&mut rng, &dims, opts.prompt_len),
                    max_new_tokens: opts.max_new,
                    eos: None,
                    sampling: Sampling::Temperature {
                        temp: 0.9,
                        seed: 0x5EED ^ id as u64,
                    },
                    priority: Priority::Interactive,
                })?;
            }
            let results = sched.run()?;
            let secs = t0.elapsed().as_secs_f64();
            // resident KV high-water mark across the run — what makes
            // this report memory-comparable with BENCH_kv.json
            let kv_peak = sched.peak_kv_resident_bytes();
            let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
            let tok_s = tokens as f64 / secs.max(1e-9);
            let mut ttft: Vec<f64> =
                results.iter().map(|r| r.ttft.as_secs_f64() * 1e3).collect();
            let mut qwait: Vec<f64> = results
                .iter()
                .map(|r| r.queue_wait.as_secs_f64() * 1e3)
                .collect();
            let mut itl: Vec<f64> = results
                .iter()
                .flat_map(|r| r.itl.iter().map(|d| d.as_secs_f64() * 1e3))
                .collect();
            let speedup = tok_s / base_tok_s;
            if c == largest_c {
                cfg_speedup = speedup;
            }
            let [ttft_p50, ttft_p95] =
                crate::stats::percentiles(&mut ttft, [50.0, 95.0]);
            let [qwait_p50, qwait_p95] =
                crate::stats::percentiles(&mut qwait, [50.0, 95.0]);
            let [itl_p50, itl_p95] =
                crate::stats::percentiles(&mut itl, [50.0, 95.0]);
            println!(
                "   c{c:<3}: {tok_s:8.1} tok/s  ttft p50 {ttft_p50:6.1} ms  \
                 p95 {ttft_p95:6.1} ms  itl p50 {itl_p50:6.2} ms  \
                 p95 {itl_p95:6.2} ms  peak KV {kv_peak} B  \
                 ({speedup:.2}x vs re-forward)",
            );
            conc_entries.push((
                format!("c{c}"),
                json::obj(vec![
                    ("requests", json::num(n_req as f64)),
                    ("tokens", json::num(tokens as f64)),
                    ("tok_per_s", json::num(tok_s)),
                    ("ttft_p50_ms", json::num(ttft_p50)),
                    ("ttft_p95_ms", json::num(ttft_p95)),
                    // submit → admission, split out of ttft so SLO
                    // readers can separate queueing from decode latency
                    ("queue_wait_p50_ms", json::num(qwait_p50)),
                    ("queue_wait_p95_ms", json::num(qwait_p95)),
                    ("itl_p50_ms", json::num(itl_p50)),
                    ("itl_p95_ms", json::num(itl_p95)),
                    ("kv_peak_bytes", json::num(kv_peak as f64)),
                    ("speedup_vs_reforward", json::num(speedup)),
                ]),
            ));
        }
        if cfg_speedup.is_finite() {
            min_speedup = min_speedup.min(cfg_speedup);
        }

        // shard scaling: the largest concurrency again on sharded
        // models with the inner GEMM pinned serial, so added cores come
        // from shard fan-out alone. Gated twice per shard count: the
        // prefill logits must equal the unsharded bits, and the greedy
        // scheduler stream must equal the shards=1 stream.
        let gate_prompt = prompt(&mut rng, &dims, opts.prompt_len);
        let gate_logits = model.forward(&gate_prompt, 1, opts.prompt_len)?;
        let gate_stream = generate_reforward(
            &model,
            &gate_prompt,
            opts.max_new.min(4),
            None,
            &Sampling::Greedy,
        )?;
        let mut shard_entries: Vec<(String, Json)> = Vec::new();
        let mut shards1_tok_s = f64::NAN;
        for &shards in &opts.shard_counts {
            let smodel = Arc::new(
                PackedModel::build_sharded(
                    &dims,
                    &params,
                    qcfg,
                    block_size,
                    operand_cache(),
                    shards,
                )?
                .with_gemm(crate::quant::gemm::PackedGemm::serial()),
            );
            let logits = smodel.forward(&gate_prompt, 1, opts.prompt_len)?;
            anyhow::ensure!(
                logits.len() == gate_logits.len()
                    && logits
                        .iter()
                        .zip(&gate_logits)
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                "{label}: shards={shards} prefill logits diverge from \
                 shards=1 — refusing to time"
            );
            let mut sched = Scheduler::new(
                DecodeEngine::new(smodel.clone())?,
                SchedulerConfig::default(),
            );
            sched.submit(DecodeRequest {
                id: 0,
                prompt: gate_prompt.clone(),
                max_new_tokens: opts.max_new.min(4),
                eos: None,
                sampling: Sampling::Greedy,
                priority: Priority::Interactive,
            })?;
            let stream = sched.run()?;
            anyhow::ensure!(
                stream.first().map(|r| r.tokens.as_slice())
                    == Some(gate_stream.as_slice()),
                "{label}: shards={shards} token stream diverges from \
                 shards=1 — refusing to time"
            );
            let n_req = largest_c * opts.rounds;
            let mut sched = Scheduler::new(
                DecodeEngine::new(smodel)?,
                SchedulerConfig {
                    max_active: largest_c,
                    max_prefill_per_step: largest_c,
                    ..SchedulerConfig::default()
                },
            );
            let t0 = Instant::now();
            for id in 0..n_req {
                sched.submit(DecodeRequest {
                    id: id as u64,
                    prompt: prompt(&mut rng, &dims, opts.prompt_len),
                    max_new_tokens: opts.max_new,
                    eos: None,
                    sampling: Sampling::Temperature {
                        temp: 0.9,
                        seed: 0x57A2 ^ id as u64,
                    },
                    priority: Priority::Interactive,
                })?;
            }
            let results = sched.run()?;
            let secs = t0.elapsed().as_secs_f64();
            let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
            let tok_s = tokens as f64 / secs.max(1e-9);
            if shards == 1 {
                shards1_tok_s = tok_s;
            }
            let speedup = tok_s / shards1_tok_s;
            println!(
                "   shards={shards}: {tok_s:8.1} tok/s at c{largest_c} \
                 ({speedup:.2}x vs 1 shard, stream-exact)"
            );
            shard_entries.push((
                format!("s{shards}"),
                json::obj(vec![
                    ("shards", json::num(shards as f64)),
                    ("tokens", json::num(tokens as f64)),
                    ("tok_per_s", json::num(tok_s)),
                    ("bit_exact", Json::Bool(true)),
                    (
                        "speedup_vs_1shard",
                        if speedup.is_finite() {
                            json::num(speedup)
                        } else {
                            Json::Null
                        },
                    ),
                ]),
            ));
        }

        // speculative axis (`--spec`): this config's model as the
        // verify target, a fixed FP4/UE5M3 draft proposing k tokens.
        // Gated stream-exact against the re-forward oracle per depth
        // before timing, like every other number in this report.
        let mut spec_entries: Vec<(String, Json)> = Vec::new();
        if !opts.spec_ks.is_empty() {
            let draft_cfg = PerLayerQConfig::uniform(
                crate::runtime::qconfig::QConfig::fp4("ue5m3")?,
            );
            let draft = Arc::new(PackedModel::build(
                &dims,
                &params,
                &draft_cfg,
                block_size,
                operand_cache(),
            )?);
            for &k in &opts.spec_ks {
                let engine = super::spec::SpecDecodeEngine::new(
                    model.clone(),
                    draft.clone(),
                    k,
                )?;
                let gp = prompt(&mut rng, &dims, opts.prompt_len);
                let want = generate_reforward(
                    &model,
                    &gp,
                    opts.max_new.min(4),
                    None,
                    &Sampling::Greedy,
                )?;
                let got = engine.generate(
                    &gp,
                    opts.max_new.min(4),
                    None,
                    &Sampling::Greedy,
                )?;
                anyhow::ensure!(
                    got.tokens == want,
                    "{label}: k={k} speculative stream {:?} != re-forward \
                     stream {want:?} — refusing to time",
                    got.tokens
                );
                let t0 = Instant::now();
                let mut tokens = 0usize;
                let (mut proposed, mut accepted) = (0usize, 0usize);
                for _ in 0..opts.baseline_requests {
                    let p = prompt(&mut rng, &dims, opts.prompt_len);
                    let o = engine.generate(
                        &p,
                        opts.max_new,
                        None,
                        &Sampling::Greedy,
                    )?;
                    tokens += o.tokens.len();
                    proposed += o.proposed;
                    accepted += o.accepted;
                }
                let secs = t0.elapsed().as_secs_f64();
                let tok_s = tokens as f64 / secs.max(1e-9);
                let acc = if proposed == 0 {
                    1.0
                } else {
                    accepted as f64 / proposed as f64
                };
                println!(
                    "   spec k={k}: {tok_s:8.1} tok/s  acceptance {acc:5.3} \
                     (fp4/ue5m3 draft, stream-exact)"
                );
                spec_entries.push((
                    format!("k{k}"),
                    json::obj(vec![
                        ("k", json::num(k as f64)),
                        ("tok_per_s", json::num(tok_s)),
                        ("acceptance", json::num(acc)),
                        ("stream_exact", Json::Bool(true)),
                    ]),
                ));
            }
        }

        config_entries.push((
            label.clone(),
            json::obj(vec![
                ("qconfig", json::s(&qcfg.id())),
                ("bit_exact", Json::Bool(true)),
                ("build_ms", json::num(build_ms)),
                ("reforward_tok_per_s", json::num(base_tok_s)),
                ("concurrency", json::obj_owned(conc_entries)),
                ("shards", json::obj_owned(shard_entries)),
                ("spec", json::obj_owned(spec_entries)),
            ]),
        ));
    }

    let pass = min_speedup.is_finite() && min_speedup >= 2.0;
    println!(
        "\n   acceptance target (cached decode >= 2.00x re-forward at \
         c{largest_c}): {}",
        if opts.smoke {
            "n/a (smoke shapes)".to_string()
        } else if pass {
            format!("PASS (min {min_speedup:.2}x)")
        } else {
            format!("MISS (min {min_speedup:.2}x, host-dependent)")
        }
    );
    let report = json::obj(vec![
        ("bench", json::s("decode")),
        ("smoke", Json::Bool(opts.smoke)),
        // the vector kernel every packed GEMM in this run dispatched to
        // (ISSUE 7 simd axis; "scalar" = no vector unit or pinned off)
        ("simd_kernel", json::s(crate::util::simd::kernel_name())),
        (
            "model",
            json::obj(vec![
                ("vocab", json::num(dims.vocab as f64)),
                ("d_model", json::num(dims.d_model as f64)),
                ("n_heads", json::num(dims.n_heads as f64)),
                ("n_layers", json::num(dims.n_layers as f64)),
                ("d_ff", json::num(dims.d_ff as f64)),
                ("seq_len", json::num(dims.seq_len as f64)),
                ("block_size", json::num(block_size as f64)),
            ]),
        ),
        ("prompt_len", json::num(opts.prompt_len as f64)),
        ("max_new", json::num(opts.max_new as f64)),
        (
            "shard_counts",
            json::arr(
                opts.shard_counts.iter().map(|&s| json::num(s as f64)),
            ),
        ),
        (
            "kv_bytes_per_position",
            json::num(crate::hw::memory::kv_exact_position_bytes(
                dims.d_model,
                dims.n_layers,
            ) as f64),
        ),
        ("configs", json::obj_owned(config_entries)),
        ("target_speedup", json::num(2.0)),
        (
            "min_concurrent_speedup",
            if min_speedup.is_finite() {
                json::num(min_speedup)
            } else {
                Json::Null
            },
        ),
        // the 2x target is defined on the full shapes only; smoke runs
        // record null so trajectory tooling can't misread tiny-shape
        // ratios as an acceptance verdict
        (
            "pass",
            if opts.smoke { Json::Null } else { Json::Bool(pass) },
        ),
    ]);
    std::fs::write(&opts.out, report.to_string())
        .with_context(|| format!("writing {}", opts.out.display()))?;
    println!("   wrote {}", opts.out.display());
    Ok(report)
}
