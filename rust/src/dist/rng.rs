//! Deterministic random number generation: PCG XSL RR 128/64.
//!
//! Every experiment, test and synthetic tensor draw in the crate runs on
//! [`Pcg64`] with an explicit seed, so sweeps are reproducible point by
//! point (cache keys embed the seed — see `coordinator`). The generator
//! is O'Neill's PCG64 (128-bit LCG state, xor-shift-low + random-rotate
//! output), which passes BigCrush and is the same family numpy defaults
//! to — adequate for Monte-Carlo MSE estimation by a wide margin.

/// PCG XSL RR 128/64 generator with a Box–Muller normal cache.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// cached second output of the last Box–Muller pair
    spare_normal: Option<f64>,
}

const PCG_MUL: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;
const PCG_DEFAULT_STREAM: u128 = 0x5851_f42d_4c95_7f2d_1405_7b7e_f767_814f;

impl Pcg64 {
    /// Seed the generator (same seed ⇒ same stream, on every platform).
    pub fn new(seed: u64) -> Pcg64 {
        let mut rng = Pcg64 {
            state: 0,
            inc: (PCG_DEFAULT_STREAM << 1) | 1,
            spare_normal: None,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MUL).wrapping_add(self.inc);
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform f64 in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        const SCALE: f64 = 1.0 / (1u64 << 53) as f64;
        (self.next_u64() >> 11) as f64 * SCALE
    }

    /// Standard normal via Box–Muller (the second draw of each pair is
    /// cached, so consecutive calls cost one transcendental on average).
    pub fn standard_normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 ∈ (0, 1] so the log is finite; u2 ∈ [0, 1)
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal draw with mean `mu` and standard deviation `sigma`.
    #[inline]
    pub fn normal(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.standard_normal()
    }

    /// A zero-mean Normal(0, σ²) tensor as f32 (f64 sampling, one cast).
    pub fn normal_vec_f32(&mut self, n: usize, sigma: f64) -> Vec<f32> {
        (0..n).map(|_| (sigma * self.standard_normal()) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(123);
        let mut b = Pcg64::new(123);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(124);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg64::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(9);
        let n = 200_000;
        let mut s = 0.0;
        let mut ss = 0.0;
        for _ in 0..n {
            let z = rng.standard_normal();
            s += z;
            ss += z * z;
        }
        let mean = s / n as f64;
        let var = ss / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn normal_vec_f32_matches_sigma() {
        let mut rng = Pcg64::new(11);
        let x = rng.normal_vec_f32(1 << 16, 0.02);
        let sd = crate::stats::std_dev_f32(&x);
        assert!((sd - 0.02).abs() / 0.02 < 0.05, "σ {sd}");
    }

    #[test]
    fn normal_vec_f32_moment_bounds() {
        // The fuzz/property suites draw their inputs from normal_vec_f32;
        // pin its sampling quality so "bit-exact across random draws"
        // statements rest on inputs that actually are N(0, σ²). For
        // n = 2^17 samples the standard error of the mean is σ/√n ≈
        // 0.0028σ and of the variance ≈ σ²√(2/n) ≈ 0.0039σ², so 5-sigma
        // bounds are ~0.014σ and ~0.02σ² — loose enough to be
        // deterministic-stable across seeds, tight enough to catch a
        // broken Box–Muller or scaling bug.
        for (seed, sigma) in [(13u64, 1.0f64), (14, 5e-3), (15, 40.0)] {
            let n = 1usize << 17;
            let mut rng = Pcg64::new(seed);
            let x = rng.normal_vec_f32(n, sigma);
            let mean = x.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
            let var = x
                .iter()
                .map(|&v| (v as f64 - mean).powi(2))
                .sum::<f64>()
                / n as f64;
            assert!(
                mean.abs() < 0.014 * sigma,
                "seed {seed} σ {sigma}: mean {mean}"
            );
            let s2 = sigma * sigma;
            assert!(
                (var - s2).abs() < 0.02 * s2,
                "seed {seed} σ {sigma}: var {var} want {s2}"
            );
            // roughly symmetric: sign balance within 1% + 5·SE
            let pos = x.iter().filter(|&&v| v > 0.0).count() as f64 / n as f64;
            assert!((pos - 0.5).abs() < 0.017, "seed {seed}: P(x>0) {pos}");
        }
    }

    #[test]
    fn reference_stream_is_pinned() {
        // Guard against accidental algorithm changes: cached results and
        // golden comparisons depend on the exact stream.
        let mut rng = Pcg64::new(42);
        let first: Vec<u64> = (0..4).map(|_| rng.next_u64()).collect();
        let again: Vec<u64> = {
            let mut r = Pcg64::new(42);
            (0..4).map(|_| r.next_u64()).collect()
        };
        assert_eq!(first, again);
        // all four distinct (astronomically likely for a sane generator)
        for i in 0..4 {
            for j in i + 1..4 {
                assert_ne!(first[i], first[j]);
            }
        }
    }
}
