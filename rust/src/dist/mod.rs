//! Synthetic distribution substrate: the seeded generator ([`rng`]) and
//! the paper's "ideal distribution" family (Fig. 3(b), Fig. 8, Fig. 9
//! right column).
//!
//! The paper probes whether perplexity inversion is a quirk of real
//! weight tensors or a property of *any* narrow distribution by sweeping
//! σ across a family of shapes — Gaussian, bounded (uniform), exponential
//! tails (Laplace, logistic) and polynomial tails (Student-t). [`Ideal`]
//! reproduces that family; every member is sampled at a known base scale
//! and rescaled so the drawn tensor has a target standard deviation σ,
//! making MSE-vs-σ curves directly comparable across shapes.

pub mod rng;

pub use rng::Pcg64;

/// The ideal-distribution family of Fig. 3(b) / Fig. 8.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IdealKind {
    /// Standard normal — the reference shape (weights are near-Gaussian,
    /// Fig. 3(a)).
    Normal,
    /// Uniform on [-1, 1] — hard-bounded, no tail.
    Uniform,
    /// Laplace (b = 1) — exponential tail, peaked center.
    Laplace,
    /// Logistic (s = 1) — exponential tail, flatter center.
    Logistic,
    /// Student-t with ν = 5 — polynomial (heavy) tail.
    StudentT5,
}

impl IdealKind {
    /// Every member, in the order figures enumerate them.
    pub const ALL: [IdealKind; 5] = [
        IdealKind::Normal,
        IdealKind::Uniform,
        IdealKind::Laplace,
        IdealKind::Logistic,
        IdealKind::StudentT5,
    ];

    /// Stable display/cache-key name.
    pub fn name(&self) -> &'static str {
        match self {
            IdealKind::Normal => "normal",
            IdealKind::Uniform => "uniform",
            IdealKind::Laplace => "laplace",
            IdealKind::Logistic => "logistic",
            IdealKind::StudentT5 => "student-t5",
        }
    }
}

/// A sampler for one [`IdealKind`].
#[derive(Debug, Clone, Copy)]
pub struct Ideal {
    kind: IdealKind,
}

impl Ideal {
    /// Sampler for `kind`.
    pub fn new(kind: IdealKind) -> Ideal {
        Ideal { kind }
    }

    /// The sampler's kind.
    pub fn kind(&self) -> IdealKind {
        self.kind
    }

    /// Standard deviation of [`Ideal::sample`] at base scale (used to
    /// rescale draws to a target σ).
    pub fn base_sigma(&self) -> f64 {
        match self.kind {
            IdealKind::Normal => 1.0,
            // Var(U[-1,1]) = 1/3
            IdealKind::Uniform => 1.0 / 3f64.sqrt(),
            // Var(Laplace(b)) = 2 b²
            IdealKind::Laplace => 2f64.sqrt(),
            // Var(Logistic(s)) = π² s² / 3
            IdealKind::Logistic => std::f64::consts::PI / 3f64.sqrt(),
            // Var(t_ν) = ν / (ν - 2), ν = 5
            IdealKind::StudentT5 => (5.0f64 / 3.0).sqrt(),
        }
    }

    /// One draw at the distribution's base scale.
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        match self.kind {
            IdealKind::Normal => rng.standard_normal(),
            IdealKind::Uniform => 2.0 * rng.uniform() - 1.0,
            IdealKind::Laplace => {
                // inverse CDF on u ∈ (-1/2, 1/2]
                let u = rng.uniform() - 0.5;
                let mag = -(1.0 - 2.0 * u.abs()).max(f64::MIN_POSITIVE).ln();
                if u < 0.0 {
                    -mag
                } else {
                    mag
                }
            }
            IdealKind::Logistic => {
                // inverse CDF, clamped away from {0, 1}
                let u = rng.uniform().clamp(1e-300, 1.0 - 1e-16);
                (u / (1.0 - u)).ln()
            }
            IdealKind::StudentT5 => {
                // z / sqrt(χ²_ν / ν) with ν = 5
                let z = rng.standard_normal();
                let mut chi2 = 0.0;
                for _ in 0..5 {
                    let g = rng.standard_normal();
                    chi2 += g * g;
                }
                z / (chi2 / 5.0).max(f64::MIN_POSITIVE).sqrt()
            }
        }
    }

    /// An n-element f32 tensor rescaled to standard deviation `sigma`
    /// (in expectation; the realized sample σ is what experiments report
    /// on their x-axes).
    pub fn tensor_f32(&self, rng: &mut Pcg64, n: usize, sigma: f64) -> Vec<f32> {
        let k = sigma / self.base_sigma();
        (0..n).map(|_| (k * self.sample(rng)) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::std_dev_f32;

    #[test]
    fn every_kind_hits_target_sigma() {
        for kind in IdealKind::ALL {
            let d = Ideal::new(kind);
            let mut rng = Pcg64::new(0xD157);
            for sigma in [1e-3, 0.02, 0.5] {
                let x = d.tensor_f32(&mut rng, 1 << 16, sigma);
                let sd = std_dev_f32(&x);
                // Student-t's heavy tail converges slowest; 12% tolerance
                assert!(
                    (sd - sigma).abs() / sigma < 0.12,
                    "{}: σ target {sigma}, got {sd}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn uniform_is_bounded() {
        let d = Ideal::new(IdealKind::Uniform);
        let mut rng = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = d.sample(&mut rng);
            assert!((-1.0..=1.0).contains(&x));
        }
    }

    #[test]
    fn tail_ordering_matches_shapes() {
        // P(|x| > 3σ): uniform = 0 < normal (0.0027) < logistic (0.0086)
        // < t5 (0.0117) < laplace (0.0144) — exponential tails carry more
        // 3σ mass than the polynomial t5 tail; t5 only dominates further
        // out (it does beat laplace by 6σ, the regime behind the paper's
        // heavy-tail MSE bumps).
        let mut tails = Vec::new();
        for kind in IdealKind::ALL {
            let d = Ideal::new(kind);
            let mut rng = Pcg64::new(17);
            let n = 200_000;
            let thresh = 3.0 * d.base_sigma();
            let c = (0..n).filter(|_| d.sample(&mut rng).abs() > thresh).count();
            tails.push((kind, c as f64 / n as f64));
        }
        let get = |k: IdealKind| tails.iter().find(|(t, _)| *t == k).unwrap().1;
        assert_eq!(get(IdealKind::Uniform), 0.0);
        assert!(get(IdealKind::Normal) > 0.0);
        assert!(get(IdealKind::Logistic) > get(IdealKind::Normal));
        assert!(get(IdealKind::StudentT5) > get(IdealKind::Logistic));
        assert!(get(IdealKind::Laplace) > get(IdealKind::StudentT5));
    }

    #[test]
    fn names_are_unique() {
        let mut names: Vec<&str> =
            IdealKind::ALL.iter().map(|k| k.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), IdealKind::ALL.len());
    }
}
