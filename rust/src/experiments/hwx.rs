//! Hardware experiment renders: Fig. 4(a) datapath description, the
//! App. K synthesis comparison, and the Sec. 3.1 storage/complexity
//! tables.

use crate::hw::memory;
use crate::hw::pe::{
    self, appendix_k_comparison, lane_area, pe_area, scale_mult_complexity,
    scale_stage_delay_ps, SCALE_BF16, SCALE_E4M3, SCALE_E4M4, SCALE_E5M3,
};
use crate::report::Table;

/// Fig. 4(a): the scale-processing datapath and where UE5M3 differs.
pub fn fig4a() -> String {
    let mut out = String::from(
        "== Figure 4(a): UE5M3 scale processing in the MXFP4 MAC datapath ==\n\
         \n\
         FP4 products --> [sum of products] ----------------+\n\
         scale_a,scale_b -> [M x M mantissa mult] --------- [fused rescale] -> psum\n\
         scale exps ------> [E-bit exponent adder] -> [- psum exp (8b)] -> [align]\n\
         \n\
         UE5M3 changes ONLY the E-bit exponent adder: 4b -> 5b. Mantissa\n\
         datapath (the area driver, Sec. 3.1: M^2*K) is unchanged.\n\n",
    );
    let mut t = Table::new(
        "Scale-path area breakdown (gate equivalents, one SIMD lane)",
        &["scale fmt", "scale path GE", "lane total GE", "share"],
    );
    for fmt in [SCALE_E4M3, SCALE_E5M3, SCALE_E4M4, SCALE_BF16] {
        let lane = lane_area(fmt);
        t.row(vec![
            fmt.name.to_string(),
            format!("{:.0}", lane.mxfp4_scale_path),
            format!("{:.0}", lane.total()),
            format!("{:.2}%", 100.0 * lane.mxfp4_scale_path / lane.total()),
        ]);
    }
    out.push_str(&t.render());
    out
}

/// App. K: the E5M3-vs-E4M3 synthesis comparison.
pub fn appendix_k() -> String {
    let (darea, ddelay) = appendix_k_comparison();
    let mut t = Table::new(
        "Appendix K: PE synthesis comparison (unit-gate model)",
        &["metric", "model", "paper (4nm EDA)"],
    );
    t.row(vec![
        "PE area Δ (E5M3 vs E4M3)".into(),
        format!("{darea:+.2}%"),
        "+0.5% (negligible)".into(),
    ]);
    t.row(vec![
        "critical path Δ".into(),
        format!("{ddelay:+.1} ps"),
        "+4 ps (negligible)".into(),
    ]);
    t.row(vec![
        "PE area (E4M3) GE".into(),
        format!("{:.0}", pe_area(SCALE_E4M3)),
        "-".into(),
    ]);
    t.row(vec![
        "scale-stage delay (E4M3)".into(),
        format!("{:.0} ps", scale_stage_delay_ps(SCALE_E4M3)),
        "-".into(),
    ]);
    let mut out = t.render();
    let a44 = pe_area(SCALE_E4M4);
    let a53 = pe_area(SCALE_E5M3);
    out.push_str(&format!(
        "UE4M4 (App. J alternative) PE area: {:+.2}% vs UE5M3 — mantissa \
         repurposing is the pricier option, as the paper argues.\n",
        100.0 * (a44 - a53) / a53
    ));
    out
}

/// Sec. 3.1: storage and multiplier-complexity tables.
///
/// The "measured packed" column materializes a real
/// [`crate::quant::PackedMxTensor`] and counts its payload bytes — on
/// byte-aligned element widths it lands exactly on the analytic 8-bit
/// column, which is the point: the Sec. 3.1 formulas price real layouts.
pub fn sec31_costs() -> String {
    let mut rng = crate::dist::Pcg64::new(0x31C0);
    let mut t = Table::new(
        "Sec. 3.1: storage cost of FP4 microscaling (bytes/element)",
        &["block size", "16-bit scales", "8-bit scales", "measured packed", "halving overhead", "x vs BF16"],
    );
    for n in [8usize, 16, 32, 64, 128, 256] {
        let x = rng.normal_vec_f32(n * 64, 0.02);
        let scheme = crate::quant::QuantScheme::new(
            crate::formats::ElemFormat::FP4,
            crate::formats::UE4M3,
            n,
        );
        let measured = crate::quant::PackedMxTensor::encode(&scheme, &x)
            .map(|p| p.bits_per_element() / 8.0)
            .unwrap_or(f64::NAN);
        t.row(vec![
            n.to_string(),
            format!("{:.4}", memory::bytes_per_element(4, 16, n)),
            format!("{:.4}", memory::bytes_per_element(4, 8, n)),
            format!("{measured:.4}"),
            format!("+{:.1}%", 100.0 * memory::halving_overhead(4, 16, n)),
            format!("{:.2}", memory::compression_vs_bf16(4, 8, n)),
        ]);
    }
    let mut out = t.render();
    out.push_str(&kv_storage_table());
    let mut c = Table::new(
        "Sec. 3.1: scale-fusion multiplier complexity M²·K (K = 24b psum)",
        &["scale fmt", "M (incl implied 1)", "M²·K", "vs UE4M3"],
    );
    for (name, m) in [("UE4M3/UE5M3", 4u32), ("UE4M4", 5), ("BF16", 8), ("FP16", 11)] {
        let v = scale_mult_complexity(m, pe::PSUM_MANTISSA);
        c.row(vec![
            name.into(),
            m.to_string(),
            format!("{v:.0}"),
            format!(
                "{:.2}x",
                v / scale_mult_complexity(4, pe::PSUM_MANTISSA)
            ),
        ]);
    }
    out.push_str(&c.render());
    out.push_str(&native_gemm_table(&mut rng));
    out
}

/// The Sec. 3.1 storage model applied to the serving path's dominant
/// memory cost: KV-cache bytes per decoded position, analytic
/// ([`memory::kv_exact_position_bytes`] /
/// [`memory::kv_packed_position_bytes`]) vs the bytes a real
/// [`crate::serve::KvPool`] page codec materializes — plus a live
/// allocation check: a prefill through the paged decode engine must
/// leave the pool's exact byte accounting equal to its page-reservation
/// arithmetic.
fn kv_storage_table() -> String {
    use crate::runtime::artifacts::ModelDims;
    use crate::runtime::qconfig::{PerLayerQConfig, QConfig};
    use crate::serve::KvPool;

    // llama-8B-class serving shape for the headline numbers
    let big = ModelDims {
        vocab: 32000,
        d_model: 4096,
        n_heads: 32,
        n_layers: 32,
        d_ff: 14336,
        seq_len: 8192,
    };
    let mut t = Table::new(
        "KV-cache storage per decoded position (d_model 4096, 32 layers)",
        &["KV codec", "analytic B/pos", "pool B/pos", "x vs f32"],
    );
    let exact_b = memory::kv_exact_position_bytes(big.d_model, big.n_layers);
    let configs: [(&str, KvRowSpec); 4] = [
        ("f32 (Exact)", None),
        ("fp8_e4m3/ue4m3 bs32", Some(("fp8_e4m3", "ue4m3", 8, 1, 32))),
        ("fp4_e2m1/ue4m3 bs32", Some(("fp4_e2m1", "ue4m3", 4, 1, 32))),
        ("fp4_e2m1/ue5m3 bs8", Some(("fp4_e2m1", "ue5m3", 4, 1, 8))),
    ];
    for (label, q) in configs {
        let (qcfg, analytic, bs) = match q {
            None => (
                PerLayerQConfig::uniform(QConfig::baseline()),
                exact_b,
                32usize,
            ),
            Some((elem, scale, bits, sb, bs)) => (
                PerLayerQConfig::uniform(
                    QConfig::named(elem, scale, false).expect("known formats"),
                ),
                memory::kv_packed_position_bytes(
                    big.d_model,
                    big.n_layers,
                    bits,
                    sb,
                    bs,
                ),
                bs,
            ),
        };
        let pool = KvPool::build(&big, &qcfg, bs, 16, usize::MAX)
            .expect("buildable codec");
        t.row(vec![
            label.to_string(),
            analytic.to_string(),
            pool.position_bytes().to_string(),
            format!("{:.2}", exact_b as f64 / pool.position_bytes() as f64),
        ]);
    }
    let mut out = t.render();

    // live check on a tiny model: allocate through a real prefill and
    // compare the pool's exact accounting against its reservation math
    let check = || -> crate::Result<bool> {
        use crate::model::weights::Params;
        use crate::serve::cache::operand_cache;
        use crate::serve::{DecodeEngine, PackedModel};
        let dims = ModelDims {
            vocab: 32,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            d_ff: 64,
            seq_len: 16,
        };
        let params = Params::init_surrogate(&dims, 7);
        let qcfg = PerLayerQConfig::uniform(QConfig::baseline());
        let model = std::sync::Arc::new(PackedModel::build(
            &dims,
            &params,
            &qcfg,
            8,
            operand_cache(),
        )?);
        let pool = KvPool::build(
            &dims,
            &PerLayerQConfig::uniform(QConfig::fp4("ue4m3")?),
            8,
            4,
            1 << 20,
        )?;
        let engine = DecodeEngine::with_pool(model, pool.clone())?;
        let mut kv = engine.new_kv();
        engine.prefill(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10], &mut kv)?;
        Ok(pool.used_bytes() == pool.bytes_for_positions(10)
            && kv.resident_bytes() == pool.used_bytes())
    };
    out.push_str(&format!(
        "Live paged-prefill accounting (FP4 KV pages, 10 positions): {}\n",
        match check() {
            Ok(true) => "exact",
            Ok(false) => "MISMATCH (bug!)",
            Err(_) => "unavailable",
        }
    ));
    out
}

/// `(elem name, scale name, elem bits, scale bytes, block size)`.
type KvRowSpec = Option<(&'static str, &'static str, u32, usize, usize)>;

/// The Sec. 3.1 byte accounting priced on a real compute path: GEMM
/// operands for the native packed engine ([`crate::quant::gemm`]), with
/// a live bit-exactness check of the engine against decoding the same
/// operands and running the f32 reference.
fn native_gemm_table(rng: &mut crate::dist::Pcg64) -> String {
    use crate::quant::gemm::{GemmOperand, PackedGemm};
    use crate::quant::matmul::matmul_t;

    let (m, k, n) = (64usize, 64, 64);
    let scheme = crate::quant::QuantScheme::new(
        crate::formats::ElemFormat::FP4,
        crate::formats::UE5M3,
        32,
    );
    let x = rng.normal_vec_f32(m * k, 5e-3);
    let w = rng.normal_vec_f32(k * n, 5e-3);
    let mut t = Table::new(
        "Native packed GEMM operands, FP4/UE5M3 bs32 (64x64x64 check)",
        &["operand", "packed bytes", "f32 bytes", "ratio"],
    );
    let xo = GemmOperand::quantize(&scheme, &x, m, k).expect("packable");
    let wo =
        GemmOperand::quantize_transposed(&scheme, &w, k, n).expect("packable");
    for (name, op, f32_bytes) in
        [("activations m x k", &xo, 4 * m * k), ("weights (n x k)ᵀ", &wo, 4 * k * n)]
    {
        t.row(vec![
            name.to_string(),
            op.payload_bytes().to_string(),
            f32_bytes.to_string(),
            format!("{:.2}x", f32_bytes as f64 / op.payload_bytes() as f64),
        ]);
    }
    let mut out = t.render();
    let native = PackedGemm::serial().matmul(&xo, &wo).expect("engine runs");
    let reference = matmul_t(&xo.decode(), &wo.decode(), m, k, n);
    let exact = native
        .iter()
        .zip(&reference)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    out.push_str(&format!(
        "Engine vs dequantize+f32 reference on these operands: {}\n",
        if exact { "bit-exact" } else { "MISMATCH (bug!)" }
    ));
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders_are_nonempty() {
        assert!(super::fig4a().contains("UE5M3"));
        assert!(super::appendix_k().contains("PE area"));
        let costs = super::sec31_costs();
        assert!(costs.contains("bytes/element"));
        // the native-GEMM check must confirm bit-exactness inline
        assert!(costs.contains("bit-exact"), "{costs}");
        // ... and the KV storage table must confirm the live pool
        // accounting check inline
        assert!(costs.contains("KV-cache storage"), "{costs}");
        assert!(
            costs.contains("10 positions): exact"),
            "live KV pool accounting check failed:\n{costs}"
        );
    }
}
