//! Theory figures: the framework curves against Normal-distribution
//! experiments (Figs. 3(c), 10, 11, 12, 13, 15).

use anyhow::Result;

use super::synth::normal_mse_curve;
use super::Ctx;
use crate::coordinator::Job;
use crate::formats::{scale_format, ElemFormat};
use crate::quant::error::mse_vs_sigma;
use crate::quant::QuantScheme;
use crate::report::{ascii_loglog, Series, Table};
use crate::stats::{chi2_log, geomspace};
use crate::theory;
use crate::util::json::{arr, num, obj, Json};

fn sigma_grid(ctx: &Ctx) -> Vec<f64> {
    geomspace(1e-4, 2.0, if ctx.fast { 24 } else { 48 })
}

fn theory_curve_job(
    key: String,
    elem: ElemFormat,
    scale_name: &'static str,
    sigmas: Vec<f64>,
    n: usize,
) -> Job {
    Job::pure(key, move || {
        let scale = scale_format(scale_name).unwrap();
        Ok(arr(sigmas.iter().map(|&s| {
            let b = theory::mse_quantized_scales(&elem, &scale, s, n);
            obj(vec![
                ("sigma", num(s)),
                ("total", num(b.total())),
                ("xi_ne", num(b.xi_ne_xmax)),
                ("xi_eq", num(b.xi_eq_xmax)),
                ("s_zero", num(b.s_zero)),
            ])
        })))
    })
}

fn series_from(pts: &Json, field: &str, name: &str) -> Result<Series> {
    let mut s = Series::new(name);
    for p in pts.as_arr()? {
        s.push(p.get("sigma")?.as_f64()?, p.get(field)?.as_f64()?);
    }
    Ok(s)
}

fn experiment_curve(
    ctx: &mut Ctx,
    tag: &str,
    elem: ElemFormat,
    scale_name: &str,
    bs: usize,
) -> Result<Json> {
    let sigmas = sigma_grid(ctx);
    let per_point = if ctx.fast { 1 << 15 } else { 1 << 17 };
    let key = format!(
        "{tag}/exp/{}/{scale_name}/bs{bs}/k{}/n{per_point}",
        elem.name(),
        sigmas.len()
    );
    // elem/scale are Copy'able small values; recompute inside the job
    let elem2 = elem;
    let scale_name2 = scale_name.to_string();
    ctx.cached(&key, move |_| {
        let scale = scale_format(&scale_name2).unwrap();
        let scheme = QuantScheme::new(elem2, scale, bs);
        let mut rng = crate::dist::Pcg64::new(0xE0 ^ bs as u64);
        Ok(arr(sigmas.iter().map(|&s| {
            let x = rng.normal_vec_f32(per_point, s);
            let (sig, mse) = mse_vs_sigma(&scheme, &x);
            obj(vec![("sigma", num(sig)), ("mse", num(mse))])
        })))
    })
}

fn chi2_of(theory_pts: &Json, exp_pts: &Json) -> Result<f64> {
    let t: Vec<f64> = theory_pts
        .as_arr()?
        .iter()
        .map(|p| p.get("total").unwrap().as_f64().unwrap())
        .collect();
    let e: Vec<f64> = exp_pts
        .as_arr()?
        .iter()
        .map(|p| p.get("mse").unwrap().as_f64().unwrap())
        .collect();
    Ok(chi2_log(&t, &e))
}

/// Fig. 3(c): theory vs experiment + the three error contributions.
pub fn fig3c(ctx: &mut Ctx) -> Result<String> {
    let bs = 16;
    let sigmas = sigma_grid(ctx);
    let key = format!("fig3c/theory/fp4/ue4m3/bs{bs}/k{}", sigmas.len());
    let jobs = vec![theory_curve_job(
        key,
        ElemFormat::FP4,
        "ue4m3",
        sigmas,
        bs,
    )];
    let th = ctx.pool.run(jobs, &mut ctx.cache)?.remove(0).value;
    let ex = experiment_curve(ctx, "fig3c", ElemFormat::FP4, "ue4m3", bs)?;
    let chi2 = chi2_of(&th, &ex)?;
    let series = vec![
        series_from(&th, "total", "theory total")?,
        {
            let mut s = Series::new("experiment (Normal)");
            for p in ex.as_arr()? {
                s.push(p.get("sigma")?.as_f64()?, p.get("mse")?.as_f64()?);
            }
            s
        },
        series_from(&th, "xi_ne", "MSE_{xi != xmax}")?,
        series_from(&th, "xi_eq", "MSE_{xi = xmax}")?,
        series_from(&th, "s_zero", "MSE_{s = 0}")?,
    ];
    Ok(format!(
        "== Figure 3(c): theory vs experiment + 3 contributions (FP4+UE4M3, bs {bs}) ==\n{}\nlog-χ² (theory vs experiment) = {chi2:.2e}  (paper: ≈4e-8 in its own units)\n",
        ascii_loglog(&series, 72, 22)
    ))
}

/// Fig. 10: non-quantized scales, theory vs experiment, across bs.
pub fn fig10(ctx: &mut Ctx) -> Result<String> {
    let sigmas = sigma_grid(ctx);
    let per_point = if ctx.fast { 1 << 15 } else { 1 << 17 };
    let mut out = String::new();
    let mut table = Table::new(
        "Figure 10: non-quantized scales — theory vs Normal experiment",
        &["block size", "log-χ²", "verdict"],
    );
    for bs in [4usize, 8, 16, 32] {
        let tkey =
            format!("fig10/theory/bs{bs}/k{}", sigmas.len());
        let sg = sigmas.clone();
        let th = ctx.cached(&tkey, move |_| {
            Ok(arr(sg.iter().map(|&s| {
                obj(vec![
                    ("sigma", num(s)),
                    (
                        "total",
                        num(theory::mse_unquantized_scales(
                            &ElemFormat::FP4,
                            s,
                            bs,
                        )),
                    ),
                ])
            })))
        })?;
        let ekey = format!("fig10/exp/bs{bs}/k{}/n{per_point}", sigmas.len());
        let sg = sigmas.clone();
        let ex = ctx.cached(&ekey, move |_| {
            Ok(normal_mse_curve("bf16", bs, sg.len(), per_point, 0x10 ^ bs as u64))
        })?;
        let chi2 = chi2_of(&th, &ex)?;
        table.row(vec![
            format!("{bs}"),
            format!("{chi2:.2e}"),
            if chi2 < 1e-3 { "agree" } else { "DISAGREE" }.into(),
        ]);
        if bs == 16 {
            let series = vec![
                series_from(&th, "total", "theory")?,
                {
                    let mut s = Series::new("experiment");
                    for p in ex.as_arr()? {
                        s.push(
                            p.get("sigma")?.as_f64()?,
                            p.get("mse")?.as_f64()?,
                        );
                    }
                    s
                },
            ];
            out.push_str(&ascii_loglog(&series, 72, 16));
        }
    }
    Ok(format!("{}{out}", table.render()))
}

/// Fig. 11: quantized UE4M3 scales across bs, with crossovers.
pub fn fig11(ctx: &mut Ctx) -> Result<String> {
    let sigmas = sigma_grid(ctx);
    let mut jobs = Vec::new();
    for bs in [4usize, 8, 16, 32] {
        jobs.push(theory_curve_job(
            format!("fig11/theory/bs{bs}/k{}", sigmas.len()),
            ElemFormat::FP4,
            "ue4m3",
            sigmas.clone(),
            bs,
        ));
    }
    let th = ctx.pool.run(jobs, &mut ctx.cache)?;
    let mut series = Vec::new();
    let mut table = Table::new(
        "Figure 11: theory vs experiment (FP4+UE4M3) across block sizes",
        &["block size", "log-χ²", "verdict"],
    );
    for (i, bs) in [4usize, 8, 16, 32].into_iter().enumerate() {
        let ex = experiment_curve(ctx, "fig11", ElemFormat::FP4, "ue4m3", bs)?;
        let chi2 = chi2_of(&th[i].value, &ex)?;
        table.row(vec![
            format!("{bs}"),
            format!("{chi2:.2e}"),
            if chi2 < 1e-3 { "agree" } else { "DISAGREE" }.into(),
        ]);
        series.push(series_from(&th[i].value, "total", &format!("theory bs{bs}"))?);
    }
    // crossover table: σ where bs8 curve exceeds bs16 curve (theory)
    let cross = crossover(&th[1].value, &th[2].value)?;
    let mut out = table.render();
    out.push_str(&ascii_loglog(&series, 72, 20));
    out.push_str(&format!(
        "theory bs8-vs-bs16 crossover: σ ≈ {} (paper: ≈2e-2)\n",
        cross.map(|c| format!("{c:.2e}")).unwrap_or("none".into())
    ));
    Ok(out)
}

fn crossover(a: &Json, b: &Json) -> Result<Option<f64>> {
    // largest σ where curve a (finer) exceeds curve b (coarser)
    let pa = a.as_arr()?;
    let pb = b.as_arr()?;
    let mut out = None;
    for (x, y) in pa.iter().zip(pb) {
        let s = x.get("sigma")?.as_f64()?;
        if x.get("total")?.as_f64()? > y.get("total")?.as_f64()? {
            out = Some(s);
        }
    }
    Ok(out)
}

/// Fig. 12: the three contributions across bs 4/8/16/32.
pub fn fig12(ctx: &mut Ctx) -> Result<String> {
    let sigmas = sigma_grid(ctx);
    let mut jobs = Vec::new();
    for bs in [4usize, 8, 16, 32] {
        jobs.push(theory_curve_job(
            format!("fig11/theory/bs{bs}/k{}", sigmas.len()), // shared key
            ElemFormat::FP4,
            "ue4m3",
            sigmas.clone(),
            bs,
        ));
    }
    let th = ctx.pool.run(jobs, &mut ctx.cache)?;
    let mut out = String::new();
    for (i, bs) in [4usize, 8, 16, 32].into_iter().enumerate() {
        let v = &th[i].value;
        let series = vec![
            series_from(v, "total", "total")?,
            series_from(v, "xi_ne", "xi != xmax")?,
            series_from(v, "xi_eq", "xi = xmax")?,
            series_from(v, "s_zero", "s = 0")?,
        ];
        out.push_str(&format!(
            "== Figure 12 (bs {bs}): error contributions ==\n{}",
            ascii_loglog(&series, 64, 14)
        ));
        // dominance summary (App. F.4)
        let pts = v.as_arr()?;
        let dom = |p: &Json| -> Result<&'static str> {
            let ne = p.get("xi_ne")?.as_f64()?;
            let eq = p.get("xi_eq")?.as_f64()?;
            let sz = p.get("s_zero")?.as_f64()?;
            Ok(if sz > ne && sz > eq {
                "s=0"
            } else if eq > ne {
                "xi=xmax"
            } else {
                "xi!=xmax"
            })
        };
        out.push_str(&format!(
            "  dominant at σ=1e-4: {} | σ=5e-3: {} | σ=0.5: {}\n",
            dom(&pts[0])?,
            dom(&pts[pts.len() / 2])?,
            dom(pts.last().unwrap())?
        ));
    }
    Ok(out)
}

/// Fig. 13: INT4 elements (App. G), theory vs experiment.
pub fn fig13(ctx: &mut Ctx) -> Result<String> {
    let sigmas = sigma_grid(ctx);
    let mut jobs = Vec::new();
    for bs in [8usize, 16] {
        jobs.push(theory_curve_job(
            format!("fig13/theory/int4/bs{bs}/k{}", sigmas.len()),
            ElemFormat::INT4,
            "ue4m3",
            sigmas.clone(),
            bs,
        ));
    }
    let th = ctx.pool.run(jobs, &mut ctx.cache)?;
    let mut table = Table::new(
        "Figure 13: INT4 microscaling with UE4M3 scales — theory vs experiment",
        &["block size", "log-χ²", "verdict"],
    );
    let mut series = Vec::new();
    for (i, bs) in [8usize, 16].into_iter().enumerate() {
        let ex =
            experiment_curve(ctx, "fig13", ElemFormat::INT4, "ue4m3", bs)?;
        let chi2 = chi2_of(&th[i].value, &ex)?;
        table.row(vec![
            format!("{bs}"),
            format!("{chi2:.2e}"),
            if chi2 < 1e-3 { "agree" } else { "DISAGREE" }.into(),
        ]);
        series.push(series_from(
            &th[i].value,
            "total",
            &format!("theory bs{bs}"),
        )?);
    }
    let cross = crossover(&th[0].value, &th[1].value)?;
    let mut out = table.render();
    out.push_str(&ascii_loglog(&series, 72, 16));
    out.push_str(&format!(
        "INT4 bs8-vs-bs16 crossover: σ ≈ {} (paper: ≈1.5e-2, below FP4's ≈2e-2)\n",
        cross.map(|c| format!("{c:.2e}")).unwrap_or("none".into())
    ));
    Ok(out)
}

/// Fig. 15: FP6 scale formats UE5M1 / UE4M2 (App. H), theory.
pub fn fig15(ctx: &mut Ctx) -> Result<String> {
    let sigmas = sigma_grid(ctx);
    let mut out = String::new();
    for (scale_name, label) in
        [("ue5m1", "Figure 15(a): FP6 UE5M1 scales"), ("ue4m2", "Figure 15(b): FP6 UE4M2 scales")]
    {
        let mut jobs = Vec::new();
        for bs in [4usize, 8, 16, 32] {
            jobs.push(theory_curve_job(
                format!("fig15/theory/{scale_name}/bs{bs}/k{}", sigmas.len()),
                ElemFormat::FP4,
                if scale_name == "ue5m1" { "ue5m1" } else { "ue4m2" },
                sigmas.clone(),
                bs,
            ));
        }
        let th = ctx.pool.run(jobs, &mut ctx.cache)?;
        let mut series = Vec::new();
        for (i, bs) in [4usize, 8, 16, 32].into_iter().enumerate() {
            series.push(series_from(
                &th[i].value,
                "total",
                &format!("bs{bs}"),
            )?);
        }
        let cross = crossover(&th[1].value, &th[2].value)?;
        let cross_txt = match cross {
            // UE5M1's huge dynamic range pushes any residual crossover
            // into the deep s=0 regime, below the paper's plotted range
            Some(c) if c < 1e-3 => format!(
                "σ ≈ {c:.2e} (deep s=0 regime only — none in the paper's plotted range)"
            ),
            Some(c) => format!("σ ≈ {c:.2e}"),
            None => "none".to_string(),
        };
        out.push_str(&format!(
            "== {label} ==\n{}bs8-vs-bs16 crossover: {} (paper: none for UE5M1; ≈3.8e-2 for UE4M2)\n",
            ascii_loglog(&series, 72, 16),
            cross_txt
        ));
    }
    Ok(out)
}
