//! Figure/table generators: one entry point per paper artifact.
//!
//! `DESIGN.md §4` maps every figure and table of the paper to a generator
//! here; the CLI (`microscale figure <id>` / `table <id>`) and the
//! `paper_tables` bench target both dispatch into this module. Results are
//! cached in `results/cache.json` (sweeps re-run incrementally) and
//! rendered as aligned tables + ASCII log-log plots, with CSVs under
//! `results/`.

pub mod hwx;
pub mod kvx;
pub mod ppl;
pub mod synth;
pub mod theory_figs;

use std::cell::OnceCell;
use std::path::PathBuf;

use anyhow::{Context as _, Result};

use crate::coordinator::sink::Sink;
use crate::coordinator::{Pool, ResultCache};
use crate::runtime::{Manifest, Session};
use crate::util::json::Json;

/// Shared experiment context: directories, cache, worker pool, lazy PJRT
/// session.
pub struct Ctx {
    pub artifacts_dir: PathBuf,
    pub results_dir: PathBuf,
    pub models_dir: PathBuf,
    /// reduce sample counts / grids (bench + smoke runs)
    pub fast: bool,
    /// training steps for the base model (experiments needing ppl)
    pub train_steps: usize,
    pub pool: Pool,
    pub cache: ResultCache,
    session: OnceCell<Session>,
}

impl Ctx {
    pub fn new(
        artifacts_dir: PathBuf,
        results_dir: PathBuf,
        models_dir: PathBuf,
        fast: bool,
    ) -> Result<Ctx> {
        std::fs::create_dir_all(&results_dir).ok();
        std::fs::create_dir_all(&models_dir).ok();
        let cache = ResultCache::open(&results_dir.join("cache.json"))?;
        Ok(Ctx {
            artifacts_dir,
            results_dir,
            models_dir,
            fast,
            train_steps: 240,
            pool: Pool::default(),
            cache,
            session: OnceCell::new(),
        })
    }

    pub fn default_dirs(fast: bool) -> Result<Ctx> {
        Ctx::new(
            PathBuf::from("artifacts"),
            PathBuf::from("results"),
            PathBuf::from("models"),
            fast,
        )
    }

    pub fn sink(&self) -> Result<Sink> {
        Sink::new(&self.results_dir)
    }

    /// Lazily opened PJRT session (only figures needing the model pay
    /// for client + compilation).
    pub fn session(&self) -> Result<&Session> {
        if self.session.get().is_none() {
            let m = Manifest::load(&self.artifacts_dir)
                .context("loading artifact manifest")?;
            let s = Session::open(m)?;
            let _ = self.session.set(s);
        }
        Ok(self.session.get().unwrap())
    }

    /// Cache-through execution for runtime-bound (non-Send) work.
    pub fn cached<F>(&mut self, key: &str, f: F) -> Result<Json>
    where
        F: FnOnce(&Self) -> Result<Json>,
    {
        if let Some(v) = self.cache.get(key) {
            return Ok(v.clone());
        }
        let t = std::time::Instant::now();
        let v = f(self)?;
        log::info!("  {key} ({:.1}s)", t.elapsed().as_secs_f64());
        self.cache.put(key.to_string(), v.clone());
        Ok(v)
    }
}

/// Dispatch a figure id to its generator; returns the rendered text.
pub fn figure(ctx: &mut Ctx, id: &str) -> Result<String> {
    match id {
        "1a" => ppl::fig1(ctx, "bf16", "Figure 1(a): perplexity gap vs block size, BF16 (non-quantized) scales"),
        "1b" => ppl::fig1(ctx, "ue4m3", "Figure 1(b): perplexity gap vs block size, FP8 UE4M3 scales"),
        "2a" => synth::fig2a(ctx),
        "2b" => synth::fig2bc(ctx, "ue4m3"),
        "2c" => synth::fig2bc(ctx, "bf16"),
        "3a" => synth::fig3a(ctx),
        "3b" => synth::fig3b(ctx),
        "3c" => theory_figs::fig3c(ctx),
        "4a" => Ok(hwx::fig4a()),
        "4b" | "4c" => ppl::fig4bc(ctx),
        "5a" => ppl::fig5a(ctx),
        "5b" => ppl::fig5b(ctx),
        "6" => synth::fig6(ctx),
        "7" => synth::fig7(ctx),
        "8" => synth::fig8(ctx),
        "9" => synth::fig9(ctx),
        "10" => theory_figs::fig10(ctx),
        "11" => theory_figs::fig11(ctx),
        "12" => theory_figs::fig12(ctx),
        "13" => theory_figs::fig13(ctx),
        "14" => ppl::fig14(ctx),
        "15" => theory_figs::fig15(ctx),
        "16" => ppl::fig16(ctx),
        "17" => ppl::fig17(ctx),
        _ => anyhow::bail!("unknown figure {id:?} (see DESIGN.md §4)"),
    }
}

/// Dispatch a table id.
pub fn table(ctx: &mut Ctx, id: &str) -> Result<String> {
    match id {
        "1" => ppl::table1or3(ctx, 8),
        "2" => ppl::table2(ctx),
        "3" => ppl::table1or3(ctx, 16),
        _ => anyhow::bail!("unknown table {id:?}"),
    }
}

/// Figures that need no PJRT runtime (pure quant/theory/dist).
pub const PURE_FIGURES: [&str; 14] = [
    "2a", "2b", "2c", "3a", "3b", "3c", "4a", "6", "7", "8", "9", "10",
    "11", "12",
];
/// Figures/tables driven by model evaluation through the runtime.
pub const RUNTIME_FIGURES: [&str; 9] =
    ["1a", "1b", "4b", "5a", "5b", "14", "16", "17", "13"];
pub const ALL_TABLES: [&str; 3] = ["1", "2", "3"];
