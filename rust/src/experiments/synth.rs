//! Runtime-free experimental figures: per-block / per-tensor MSE over the
//! σ-calibrated weight ensembles and the ideal distributions
//! (Figs. 2, 3(a,b), 6, 7, 8, 9).

use anyhow::Result;

use super::Ctx;
use crate::coordinator::sink::fmt_g;
use crate::coordinator::spec::expand_jobs;
use crate::coordinator::Job;
use crate::dist::{Ideal, IdealKind, Pcg64};
use crate::formats::{scale_format, ElemFormat};
use crate::model::zoo::{profile, SigmaProfile, PROFILES};
use crate::quant::error::{fraction_fine_worse, per_block_mse_pairs, mse_vs_sigma};
use crate::quant::QuantScheme;
use crate::report::{ascii_loglog, Series, Table};
use crate::stats::{geomspace, Histogram2d};
use crate::util::json::{arr, num, obj, Json};

fn ensemble_sizes(ctx: &Ctx) -> (usize, usize) {
    // (#tensors per model profile, elements per tensor)
    if ctx.fast {
        (24, 1 << 12)
    } else {
        (64, 1 << 14)
    }
}

/// Fig. 2(a): per-block MSE density, bs 8 vs bs 16, granite-like tensor.
pub fn fig2a(ctx: &mut Ctx) -> Result<String> {
    let prof = profile("granite-like").unwrap();
    let n = if ctx.fast { 1 << 15 } else { 1 << 18 };
    let key = format!("fig2a/granite/n={n}");
    let v = ctx.cached(&key, |_| {
        let mut rng = Pcg64::new(0xF26A);
        // a single "Query weight tensor"-like draw: mixture over the
        // profile to mimic within-tensor row-scale variation
        let mut x = Vec::with_capacity(n);
        let normal = Ideal::new(IdealKind::Normal);
        while x.len() < n {
            let sigma = prof.sample_sigma(&mut rng);
            x.extend(normal.tensor_f32(&mut rng, 1 << 10, sigma));
        }
        x.truncate(n);
        let scheme = QuantScheme::new(
            ElemFormat::FP4,
            crate::formats::UE4M3,
            8,
        );
        let pairs = per_block_mse_pairs(&scheme, &x, 8, 16);
        let mut h = Histogram2d::new(48, -12.0, -2.0);
        for (f, c) in &pairs {
            h.add(*c, *f); // x: bs16 MSE, y: bs8 MSE
        }
        Ok(obj(vec![
            ("above_diagonal", num(fraction_fine_worse(&pairs))),
            ("hist_above", num(h.above_diagonal())),
            ("blocks", num(pairs.len() as f64)),
        ]))
    })?;
    let frac = v.get("above_diagonal")?.as_f64()?;
    let mut t = Table::new(
        "Figure 2(a): per-block MSE, bs 8 vs 16 (FP4 + UE4M3 scales, granite-like tensor)",
        &["metric", "value", "paper"],
    );
    t.row(vec![
        "blocks above diagonal (bs8 worse)".into(),
        format!("{:.1}%", 100.0 * frac),
        "~25%".into(),
    ]);
    t.row(vec![
        "blocks compared".into(),
        fmt_g(v.get("blocks")?.as_f64()?),
        "-".into(),
    ]);
    Ok(t.render())
}

/// Fig. 2(b,c) / Fig. 7: per-tensor MSE vs σ for model-profile ensembles,
/// bs 8 vs 16, under `scale_name` scales.
pub fn fig2bc(ctx: &mut Ctx, scale_name: &str) -> Result<String> {
    let (count, numel) = ensemble_sizes(ctx);
    let profiles = ["granite-like", "llama2-like"];
    let points: Vec<(&str, usize)> = profiles
        .iter()
        .flat_map(|p| [(*p, 8usize), (*p, 16)])
        .collect();
    let jobs = expand_jobs(points, |(pname, bs)| {
        let prof = profile(pname).unwrap();
        let key =
            format!("fig2bc/{pname}/{scale_name}/bs{bs}/c{count}/n{numel}");
        let scale_name = scale_name.to_string();
        Job::pure(key, move || {
            Ok(ensemble_points(&prof, &scale_name, bs, count, numel))
        })
    });
    let out = ctx.pool.run(jobs, &mut ctx.cache)?;
    let mut series = Vec::new();
    let mut crossover_txt = String::new();
    for (i, pname) in profiles.iter().enumerate() {
        for (j, bs) in [8usize, 16].iter().enumerate() {
            let pts = &out[i * 2 + j].value;
            let mut s = Series::new(format!("{pname} bs{bs}"));
            for p in pts.as_arr()? {
                s.push(p.get("sigma")?.as_f64()?, p.get("mse")?.as_f64()?);
            }
            series.push(s);
        }
    }
    // estimate the bs8/bs16 crossover σ from binned medians over all points
    if let Some(cx) = crossover_sigma(&series) {
        crossover_txt = format!(
            "bs8-vs-bs16 crossover at σ ≈ {:.1e} (paper: ≈2e-2 for UE4M3; none for BF16)",
            cx
        );
    } else {
        crossover_txt.push_str(
            "no bs8-vs-bs16 crossover in range (paper: none for BF16 scales)",
        );
    }
    let title = if scale_name == "bf16" {
        "Figure 2(c): per-tensor MSE vs σ, BF16 scales"
    } else {
        "Figure 2(b): per-tensor MSE vs σ, FP8 UE4M3 scales"
    };
    Ok(format!(
        "== {title} ==\n{}\n{crossover_txt}\n",
        ascii_loglog(&series, 72, 20)
    ))
}

fn ensemble_points(
    prof: &SigmaProfile,
    scale_name: &str,
    bs: usize,
    count: usize,
    numel: usize,
) -> Json {
    let scale = scale_format(scale_name).unwrap();
    let mut rng = Pcg64::new(0x2BC ^ bs as u64);
    let tensors = prof.tensor_ensemble(&mut rng, count, numel);
    let scheme = QuantScheme::new(ElemFormat::FP4, scale, bs);
    arr(tensors.iter().map(|t| {
        let (sigma, mse) = mse_vs_sigma(&scheme, t);
        obj(vec![("sigma", num(sigma)), ("mse", num(mse))])
    }))
}

/// Crude crossover estimator: first σ (log-binned) where the bs8 median
/// rises above the bs16 median, scanning upward.
fn crossover_sigma(series: &[Series]) -> Option<f64> {
    let collect = |tag: &str| -> Vec<(f64, f64)> {
        series
            .iter()
            .filter(|s| s.name.contains(tag))
            .flat_map(|s| s.x.iter().cloned().zip(s.y.iter().cloned()))
            .collect()
    };
    let p8 = collect("bs8");
    let p16 = collect("bs16");
    if p8.is_empty() || p16.is_empty() {
        return None;
    }
    let edges = geomspace(1e-4, 1.0, 25);
    let med = |pts: &[(f64, f64)], lo: f64, hi: f64| -> Option<f64> {
        let mut v: Vec<f64> = pts
            .iter()
            .filter(|(x, _)| *x >= lo && *x < hi)
            .map(|(_, y)| *y)
            .collect();
        if v.is_empty() {
            return None;
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(v[v.len() / 2])
    };
    let mut last_inverted = None;
    for w in edges.windows(2) {
        if let (Some(m8), Some(m16)) =
            (med(&p8, w[0], w[1]), med(&p16, w[0], w[1]))
        {
            if m8 > m16 {
                last_inverted = Some((w[0] * w[1]).sqrt());
            }
        }
    }
    last_inverted
}

/// Fig. 3(a): model-profile points vs the Normal-distribution curve.
pub fn fig3a(ctx: &mut Ctx) -> Result<String> {
    let bs = 16;
    let sweep_n = if ctx.fast { 24 } else { 48 };
    let per_point = if ctx.fast { 1 << 15 } else { 1 << 17 };
    let key = format!("fig3a/normal/bs{bs}/k{sweep_n}/n{per_point}");
    let normal_curve = ctx.cached(&key, |_| {
        Ok(normal_mse_curve("ue4m3", bs, sweep_n, per_point, 0x3A))
    })?;
    let (count, numel) = ensemble_sizes(ctx);
    let mut series = vec![json_series("Normal (swept σ)", &normal_curve)?];
    for pname in ["granite-like", "llama2-like", "mamba-codestral-like"] {
        let prof = profile(pname).unwrap();
        let key = format!("fig3a/{pname}/bs{bs}/c{count}/n{numel}");
        let pts = ctx.cached(&key, |_| {
            Ok(ensemble_points(&prof, "ue4m3", bs, count, numel))
        })?;
        series.push(json_series(pname, &pts)?);
    }
    Ok(format!(
        "== Figure 3(a): MSE-σ, pretrained-model stand-ins vs Normal (FP4+UE4M3, bs {bs}) ==\n{}",
        ascii_loglog(&series, 72, 20)
    ))
}

pub(crate) fn normal_mse_curve(
    scale_name: &str,
    bs: usize,
    sweep_n: usize,
    per_point: usize,
    seed: u64,
) -> Json {
    let scale = scale_format(scale_name).unwrap();
    let scheme = QuantScheme::new(ElemFormat::FP4, scale, bs);
    let sigmas = geomspace(1e-4, 2.0, sweep_n);
    let mut rng = Pcg64::new(seed);
    arr(sigmas.iter().map(|&s| {
        let x = rng.normal_vec_f32(per_point, s);
        let (sig, mse) = mse_vs_sigma(&scheme, &x);
        obj(vec![("sigma", num(sig)), ("mse", num(mse))])
    }))
}

fn json_series(name: &str, pts: &Json) -> Result<Series> {
    let mut s = Series::new(name);
    for p in pts.as_arr()? {
        s.push(p.get("sigma")?.as_f64()?, p.get("mse")?.as_f64()?);
    }
    Ok(s)
}

/// Fig. 3(b) / right column of Fig. 9: MSE-σ across ideal distributions.
pub fn fig3b(ctx: &mut Ctx) -> Result<String> {
    fig_ideal_family(ctx, 16, "Figure 3(b): MSE-σ across ideal distributions (FP4+UE4M3, bs 16)")
}

fn fig_ideal_family(ctx: &mut Ctx, bs: usize, title: &str) -> Result<String> {
    let sweep_n = if ctx.fast { 20 } else { 40 };
    let per_point = if ctx.fast { 1 << 14 } else { 1 << 16 };
    let jobs = expand_jobs(IdealKind::ALL.to_vec(), |kind| {
        let key =
            format!("fig3b/{}/bs{bs}/k{sweep_n}/n{per_point}", kind.name());
        Job::pure(key, move || {
            let dist = Ideal::new(kind);
            let scheme = QuantScheme::new(
                ElemFormat::FP4,
                crate::formats::UE4M3,
                bs,
            );
            let sigmas = geomspace(1e-4, 2.0, sweep_n);
            let mut rng = Pcg64::new(0x3B ^ bs as u64);
            Ok(arr(sigmas.iter().map(|&s| {
                let x = dist.tensor_f32(&mut rng, per_point, s);
                let (sig, mse) = mse_vs_sigma(&scheme, &x);
                obj(vec![("sigma", num(sig)), ("mse", num(mse))])
            })))
        })
    });
    let out = ctx.pool.run(jobs, &mut ctx.cache)?;
    let mut series = Vec::new();
    for (kind, o) in IdealKind::ALL.iter().zip(&out) {
        series.push(json_series(kind.name(), &o.value)?);
    }
    Ok(format!("== {title} ==\n{}", ascii_loglog(&series, 72, 20)))
}

/// Fig. 6: per-block above-diagonal fractions across tensors and models.
pub fn fig6(ctx: &mut Ctx) -> Result<String> {
    let n = if ctx.fast { 1 << 14 } else { 1 << 16 };
    let mut t = Table::new(
        "Figure 6: per-block MSE bs8 vs bs16 — fraction of blocks above the diagonal (FP4+UE4M3)",
        &["model profile", "tensor draw", "above diag", "aggregate inverted?"],
    );
    let points: Vec<(SigmaProfile, u64)> = PROFILES
        .iter()
        .flat_map(|p| (0..3u64).map(move |d| (*p, d)))
        .collect();
    let jobs = expand_jobs(points, |(prof, draw)| {
        let key = format!("fig6/{}/d{draw}/n{n}", prof.name);
        Job::pure(key, move || {
            let mut rng = Pcg64::new(0xF16 ^ draw);
            let sigma = prof.sample_sigma(&mut rng);
            let x = Ideal::new(IdealKind::Normal)
                .tensor_f32(&mut rng, n, sigma);
            let scheme = QuantScheme::new(
                ElemFormat::FP4,
                crate::formats::UE4M3,
                8,
            );
            let pairs = per_block_mse_pairs(&scheme, &x, 8, 16);
            let (sf, sc) = pairs
                .iter()
                .fold((0.0, 0.0), |(a, b), (f, c)| (a + f, b + c));
            Ok(obj(vec![
                ("sigma", num(sigma)),
                ("above", num(fraction_fine_worse(&pairs))),
                ("inverted", num((sf > sc) as u8 as f64)),
            ]))
        })
    });
    let out = ctx.pool.run(jobs, &mut ctx.cache)?;
    let mut i = 0;
    for prof in PROFILES {
        for _ in 0..3 {
            let v = &out[i].value;
            t.row(vec![
                prof.name.into(),
                format!("σ={:.2e}", v.get("sigma")?.as_f64()?),
                format!("{:.1}%", 100.0 * v.get("above")?.as_f64()?),
                if v.get("inverted")?.as_f64()? > 0.5 { "yes" } else { "no" }
                    .into(),
            ]);
            i += 1;
        }
    }
    Ok(t.render())
}

/// Fig. 7: MSE vs σ across all model profiles (one bs).
pub fn fig7(ctx: &mut Ctx) -> Result<String> {
    let (count, numel) = ensemble_sizes(ctx);
    let bs = 16;
    let mut series = Vec::new();
    for prof in PROFILES {
        let key = format!("fig7/{}/bs{bs}/c{count}/n{numel}", prof.name);
        let pts = ctx.cached(&key, |_| {
            Ok(ensemble_points(&prof, "ue4m3", bs, count, numel))
        })?;
        series.push(json_series(prof.name, &pts)?);
    }
    Ok(format!(
        "== Figure 7: per-tensor MSE vs σ across model profiles (FP4+UE4M3, bs {bs}) ==\n{}",
        ascii_loglog(&series, 72, 20)
    ))
}

/// Fig. 8: shapes of the ideal distributions (moment summary).
pub fn fig8(_ctx: &mut Ctx) -> Result<String> {
    let mut t = Table::new(
        "Figure 8: ideal distribution family (shape summary at unit scale)",
        &["distribution", "σ(base)", "kurtosis", "P(|x|>3σ)"],
    );
    for kind in IdealKind::ALL {
        let d = Ideal::new(kind);
        let mut rng = Pcg64::new(8);
        let n = 200_000;
        let mut m2 = 0.0;
        let mut m4 = 0.0;
        let mut tail = 0usize;
        let base = d.base_sigma();
        for _ in 0..n {
            let x = d.sample(&mut rng);
            m2 += x * x;
            m4 += x * x * x * x;
            if x.abs() > 3.0 * base {
                tail += 1;
            }
        }
        m2 /= n as f64;
        m4 /= n as f64;
        t.row(vec![
            kind.name().into(),
            format!("{:.3}", base),
            format!("{:.2}", m4 / (m2 * m2)),
            format!("{:.4}%", 100.0 * tail as f64 / n as f64),
        ]);
    }
    Ok(t.render())
}

/// Fig. 9: MSE vs σ — Normal vs model profiles (left) and the ideal
/// family (right) — across block sizes.
pub fn fig9(ctx: &mut Ctx) -> Result<String> {
    let mut out = String::new();
    for bs in [8usize, 16, 32] {
        let sweep_n = if ctx.fast { 20 } else { 36 };
        let per_point = if ctx.fast { 1 << 14 } else { 1 << 16 };
        let key = format!("fig9/normal/bs{bs}/k{sweep_n}/n{per_point}");
        let curve = ctx.cached(&key, |_| {
            Ok(normal_mse_curve("ue4m3", bs, sweep_n, per_point, 0x9 ^ bs as u64))
        })?;
        let (count, numel) = ensemble_sizes(ctx);
        let mut series = vec![json_series("Normal", &curve)?];
        for pname in ["granite-like", "mamba-codestral-like"] {
            let prof = profile(pname).unwrap();
            let key = format!("fig9/{pname}/bs{bs}/c{count}/n{numel}");
            let pts = ctx.cached(&key, |_| {
                Ok(ensemble_points(&prof, "ue4m3", bs, count, numel))
            })?;
            series.push(json_series(pname, &pts)?);
        }
        out.push_str(&format!(
            "== Figure 9 (left, bs {bs}): models vs Normal ==\n{}",
            ascii_loglog(&series, 72, 16)
        ));
        out.push_str(&fig_ideal_family(
            ctx,
            bs,
            &format!("Figure 9 (right, bs {bs}): ideal distributions"),
        )?);
    }
    Ok(out)
}
