//! The in-vivo KV block-size anomaly sweep (`microscale kv-sweep`).
//!
//! The paper derives the block-size anomaly on weight tensors; this
//! experiment reproduces it on **live decode traces**: the post-LN,
//! post-gain K/V activations an actual KV-cached generation run leaves
//! behind — exactly the rows the serving stack's `Mx` page codec
//! ([`crate::serve::kvpool`]) quantizes. The sweep
//!
//! 1. runs a greedy generation through [`crate::serve::DecodeEngine`]
//!    over an Exact [`crate::serve::KvPool`] and captures every cached
//!    K/V row ([`crate::serve::SeqKv::layer_rows_f32`]);
//! 2. reports the rows' empirical σ per layer (the statistic Sec. 3.2
//!    ties the anomaly to);
//! 3. σ-normalizes the pooled rows onto the narrow regimes real LLM
//!    KV tensors occupy (the same model-substitution philosophy as
//!    DESIGN.md §1 — the surrogate's scale is arbitrary, the *shape*
//!    is live), and
//! 4. quantizes them across element formats {FP4, FP8} × scale formats
//!    {UE4M3, UE5M3, BF16} × block sizes, tabulating relative MSE.
//!
//! Expected verdicts, mirroring Fig. 2(b,c) in vivo: under UE4M3
//! scales the error **inverts** (smaller blocks worse — the U-shape)
//! once σ sits below the collapse threshold; under UE5M3 and BF16
//! scales it stays monotone. The `kvx` test pins the σ = 5e-3 FP4
//! verdicts.

use std::path::Path;
use std::sync::Arc;

use crate::dist::Pcg64;
use crate::formats::ElemFormat;
use crate::model::weights::Params;
use crate::quant::{fake_quant, QuantScheme};
use crate::report::Table;
use crate::runtime::artifacts::ModelDims;
use crate::runtime::qconfig::{PerLayerQConfig, QConfig};
use crate::serve::cache::operand_cache;
use crate::serve::{DecodeEngine, KvPool, PackedModel};

/// Block sizes the sweep covers (all divide the sweep model's
/// `d_model`, so blocks never span rows).
pub const BLOCK_SIZES: [usize; 4] = [4, 8, 16, 32];

/// σ targets the live rows are normalized onto: both sides of the
/// UE4M3 collapse threshold (σ ≲ 2e-2, Sec. 3.2).
pub const SIGMAS: [f64; 3] = [2e-3, 5e-3, 2e-2];

/// One (element, scale, σ-target) curve over [`BLOCK_SIZES`].
pub struct KvCurve {
    /// Element format name (`fp4_e2m1`, `fp8_e4m3`).
    pub elem: String,
    /// Scale format name (`ue4m3`, `ue5m3`, `bf16`).
    pub scale: String,
    /// σ the pooled live rows were normalized to.
    pub sigma: f64,
    /// `(block size, MSE / σ²)` points, ascending block size.
    pub points: Vec<(usize, f64)>,
}

impl KvCurve {
    /// `"inverted"` when the smallest block is ≥ 5% worse than the
    /// largest (the anomaly), `"monotone"` when it is strictly better,
    /// `"flat"` otherwise.
    pub fn verdict(&self) -> &'static str {
        let first = self.points.first().map(|p| p.1).unwrap_or(0.0);
        let last = self.points.last().map(|p| p.1).unwrap_or(0.0);
        if first > last * 1.05 {
            "inverted"
        } else if first < last {
            "monotone"
        } else {
            "flat"
        }
    }
}

/// The captured trace plus every quantization curve.
pub struct KvSweep {
    /// Per `(layer, stream)` empirical σ of the captured rows
    /// (stream 0 = K, 1 = V).
    pub trace_sigma: Vec<(usize, usize, f64)>,
    /// Values captured across layers and both streams.
    pub values: usize,
    /// Decoded positions in the trace.
    pub positions: usize,
    pub curves: Vec<KvCurve>,
}

/// Capture a live KV trace and run the sweep (`fast` shrinks the
/// generation length).
pub fn sweep(fast: bool) -> crate::Result<KvSweep> {
    let dims = ModelDims {
        vocab: 64,
        d_model: 64,
        n_heads: 2,
        n_layers: 2,
        d_ff: 128,
        seq_len: if fast { 24 } else { 48 },
    };
    let params = Params::init_surrogate(&dims, 0x5EED);
    // weights stay exact: the sweep isolates KV-cache quantization
    let qcfg = PerLayerQConfig::uniform(QConfig::baseline());
    let model = Arc::new(PackedModel::build(
        &dims,
        &params,
        &qcfg,
        16,
        operand_cache(),
    )?);
    // the trace comes off the real paged serving path (Exact codec, so
    // the captured rows are the bit-exact activations)
    let pool = KvPool::exact(&dims, 8, usize::MAX)?;
    let engine = DecodeEngine::with_pool(model.clone(), pool)?;
    let mut rng = Pcg64::new(41);
    let prompt: Vec<i32> = (0..8)
        .map(|_| (rng.next_u64() % dims.vocab as u64) as i32)
        .collect();
    let mut sampler =
        crate::serve::decode::Sampler::new(&crate::serve::Sampling::Greedy)?;
    let mut kv = engine.new_kv();
    let mut logits = engine.prefill(&prompt, &mut kv)?;
    while kv.len() < dims.seq_len {
        let tok = sampler.pick(&logits);
        logits = engine.step(&[tok], std::slice::from_mut(&mut kv))?;
    }

    let mut pooled: Vec<f32> = Vec::new();
    let mut trace_sigma = Vec::new();
    for layer in 0..dims.n_layers {
        let (k, v) = kv.layer_rows_f32(layer);
        for (si, rows) in [k, v].into_iter().enumerate() {
            trace_sigma.push((layer, si, crate::stats::std_dev_f32(&rows)));
            pooled.extend(rows);
        }
    }
    let positions = kv.len();
    let emp = crate::stats::std_dev_f32(&pooled);
    anyhow::ensure!(emp > 0.0, "degenerate KV trace (all zeros)");

    let mut curves = Vec::new();
    for &sigma in &SIGMAS {
        let scale = (sigma / emp) as f32;
        let xs: Vec<f32> = pooled.iter().map(|&v| v * scale).collect();
        for elem in ["fp4_e2m1", "fp8_e4m3"] {
            for scale_fmt in ["ue4m3", "ue5m3", "bf16"] {
                let ef = ElemFormat::from_name(elem).unwrap();
                let sf = crate::formats::scale_format(scale_fmt).unwrap();
                let mut points = Vec::new();
                for &bs in &BLOCK_SIZES {
                    let n = xs.len() - xs.len() % bs;
                    let scheme = QuantScheme::new(ef, sf, bs);
                    let q = fake_quant(&scheme, &xs[..n]);
                    let mse = crate::stats::mse_f32(&xs[..n], &q);
                    points.push((bs, mse / (sigma * sigma)));
                }
                curves.push(KvCurve {
                    elem: elem.to_string(),
                    scale: scale_fmt.to_string(),
                    sigma,
                    points,
                });
            }
        }
    }
    Ok(KvSweep { trace_sigma, values: pooled.len(), positions, curves })
}

/// Run the sweep and render it; optionally export
/// `kv_anomaly.csv` next to the other experiment sinks.
pub fn anomaly_sweep(fast: bool, csv: Option<&Path>) -> crate::Result<String> {
    let s = sweep(fast)?;
    let mut out = String::from(
        "== KV block-size anomaly on live decode traces ==\n\
         \n\
         Cached post-gain K/V rows from a KV-cached greedy generation,\n\
         sigma-normalized, quantized per block size (rel MSE = MSE/sigma^2).\n\
         The paper's anomaly, in vivo: UE4M3 inverts below the collapse\n\
         sigma; UE5M3/BF16 stay monotone.\n\n",
    );
    out.push_str(&format!(
        "trace: {} positions, {} values; per-(layer, K/V) sigma: {}\n\n",
        s.positions,
        s.values,
        s.trace_sigma
            .iter()
            .map(|(l, si, sd)| format!(
                "L{l}{} {sd:.2e}",
                if *si == 0 { "K" } else { "V" }
            ))
            .collect::<Vec<_>>()
            .join(", "),
    ));
    for &sigma in &SIGMAS {
        let mut t = Table::new(
            &format!("KV rows normalized to sigma = {sigma:.0e}"),
            &["elem", "scale", "bs4", "bs8", "bs16", "bs32", "verdict"],
        );
        for c in s.curves.iter().filter(|c| c.sigma == sigma) {
            let mut cells = vec![c.elem.clone(), c.scale.clone()];
            cells.extend(c.points.iter().map(|(_, m)| format!("{m:.3e}")));
            cells.push(match c.verdict() {
                "inverted" => "INVERTED (anomaly)".to_string(),
                v => v.to_string(),
            });
            t.row(cells);
        }
        out.push_str(&t.render());
    }
    if let Some(path) = csv {
        let mut csv_out =
            String::from("sigma_target,elem,scale,block_size,rel_mse\n");
        for c in &s.curves {
            for (bs, m) in &c.points {
                csv_out.push_str(&format!(
                    "{:.6e},{},{},{bs},{m:.6e}\n",
                    c.sigma, c.elem, c.scale
                ));
            }
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path, csv_out)?;
        out.push_str(&format!("wrote {}\n", path.display()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_trace_reproduces_the_anomaly() {
        let s = sweep(true).unwrap();
        assert!(s.positions >= 16 && s.values > 4000);
        // the Sec. 3.2 shape, on live KV rows at sigma = 5e-3: UE4M3
        // inverts (the anomaly), UE5M3 stays monotone — and UE5M3 beats
        // UE4M3 at every block size
        let find = |elem: &str, scale: &str| {
            s.curves
                .iter()
                .find(|c| c.elem == elem && c.scale == scale && c.sigma == 5e-3)
                .unwrap()
        };
        let u43 = find("fp4_e2m1", "ue4m3");
        let u53 = find("fp4_e2m1", "ue5m3");
        assert_eq!(u43.verdict(), "inverted", "{:?}", u43.points);
        assert_eq!(u53.verdict(), "monotone", "{:?}", u53.points);
        // (same 5%-noise slack as quant::tests::ue5m3_never_worse_...)
        for ((bs, a), (_, b)) in u43.points.iter().zip(&u53.points) {
            assert!(*a >= b * 0.95, "bs{bs}: ue4m3 {a} < ue5m3 {b}");
        }
    }

    #[test]
    fn render_carries_the_curves_and_verdicts() {
        let out = anomaly_sweep(true, None).unwrap();
        assert!(out.contains("INVERTED (anomaly)"));
        assert!(out.contains("monotone"));
        assert!(out.contains("ue5m3"));
        assert!(out.contains("trace:"));
    }
}
