//! Perplexity / accuracy experiments through the PJRT runtime
//! (Figs. 1, 4(b,c), 5, 14, 16, 17; Tables 1–3).
//!
//! Models: one base transformer trained in-repo on the synthetic corpus,
//! plus σ-transformed zoo variants standing in for the paper's model
//! suite (DESIGN.md §1). Every (model, format, block size) point is
//! cached, so figures sharing points (1b/5a/16...) reuse evaluations.

use std::cell::OnceCell;

use anyhow::Result;

use super::Ctx;
use crate::model::weights::Params;
use crate::model::zoo;
use crate::model::Corpus;
use crate::report::Table;
use crate::runtime::eval::{self, DeviceParams};
use crate::runtime::train::{train, TrainConfig};
use crate::runtime::QConfig;
use crate::util::json::{num, Json};

/// The model-suite stand-ins used in ppl experiments.
pub const MODELS: [&str; 4] = [
    "granite-like",
    "llama2-like",
    "llama3-like",
    "mamba-codestral-like",
];

const EVAL_SEED: u64 = 4242;
const PROBE_SEED: u64 = 777;

pub struct ModelEntry {
    pub name: String,
    pub params: Params,
    dev: OnceCell<DeviceParams>,
}

impl ModelEntry {
    fn dev(&self, ctx: &Ctx) -> Result<&DeviceParams> {
        if self.dev.get().is_none() {
            let d = DeviceParams::upload(ctx.session()?, &self.params)?;
            let _ = self.dev.set(d);
        }
        Ok(self.dev.get().unwrap())
    }
}

fn n_eval_batches(ctx: &Ctx) -> usize {
    if ctx.fast {
        2
    } else {
        8
    }
}

fn block_sweep(ctx: &Ctx) -> Vec<usize> {
    if ctx.fast {
        vec![2, 8, 16, 32, 128]
    } else {
        vec![2, 4, 8, 16, 32, 64, 128]
    }
}

/// Train (or load) the base model and build the σ-transformed zoo.
pub fn ensure_models(ctx: &mut Ctx) -> Result<Vec<ModelEntry>> {
    let steps = if ctx.fast { 60 } else { ctx.train_steps };
    let base_path = ctx.models_dir.join(format!("base-s{steps}.bin"));
    let base = if base_path.exists() {
        Params::load(&base_path)?
    } else {
        log::info!("training base model ({steps} steps)...");
        let sess = ctx.session()?;
        let m = sess.manifest().clone();
        let corpus = Corpus::default_language(m.model.vocab);
        let init = Params::init(&m, 2026);
        let cfg = TrainConfig {
            steps,
            lr: 1.5e-3,
            warmup: steps / 10 + 1,
            weight_decay: 0.01,
            seed: 1,
            log_every: (steps / 10).max(1),
        };
        let (trained, curve) = train(sess, &corpus, &init, &cfg)?;
        trained.save(&base_path)?;
        // persist the loss curve for EXPERIMENTS.md
        let rows: Vec<Vec<String>> = curve
            .iter()
            .map(|p| {
                vec![p.step.to_string(), format!("{:.4}", p.loss), format!("{:.2e}", p.lr)]
            })
            .collect();
        ctx.sink()?.csv("train_loss_curve", &["step", "loss", "lr"], &rows)?;
        trained
    };

    let n_layers = {
        let sess = ctx.session()?;
        sess.manifest().model.n_layers
    };
    let mut out = Vec::new();
    for name in MODELS {
        let path = ctx.models_dir.join(format!("{name}-s{steps}.bin"));
        let params = if path.exists() {
            Params::load(&path)?
        } else {
            let mut p = base.clone();
            let prof = zoo::profile(name).unwrap();
            zoo::apply_sigma_profile(&mut p, n_layers, &prof, 0xA11CE);
            p.save(&path)?;
            p
        };
        out.push(ModelEntry {
            name: name.to_string(),
            params,
            dev: OnceCell::new(),
        });
    }
    Ok(out)
}

/// One cached perplexity point.
pub fn ppl_point(
    ctx: &mut Ctx,
    model: &ModelEntry,
    qcfg: &QConfig,
    bs: usize,
) -> Result<f64> {
    let nb = n_eval_batches(ctx);
    let steps = if ctx.fast { 60 } else { ctx.train_steps };
    let key = format!(
        "ppl/s{steps}/{}/{}/bs{bs}/eb{nb}/seed{EVAL_SEED}",
        model.name,
        qcfg.id()
    );
    let v = ctx.cached(&key, |c| {
        let sess = c.session()?;
        let m = sess.manifest();
        let corpus = Corpus::default_language(m.model.vocab);
        let batches =
            corpus.batches(EVAL_SEED, nb, m.eval_batch, m.model.seq_len + 1);
        let p =
            eval::perplexity(sess, model.dev(c)?, qcfg, bs, &batches)?;
        Ok(num(p))
    })?;
    v.as_f64()
}

/// Figs. 1(a)/1(b): perplexity gap vs block size across the model suite.
pub fn fig1(ctx: &mut Ctx, scale_name: &str, title: &str) -> Result<String> {
    let models = ensure_models(ctx)?;
    let sweep = block_sweep(ctx);
    let qcfg = QConfig::fp4(scale_name)?;
    let base_cfg = QConfig::baseline();
    let mut t = Table::new(
        title,
        &[&["block size"][..], &MODELS].concat(),
    );
    let mut gaps: Vec<Vec<f64>> = Vec::new();
    for &bs in &sweep {
        let mut row = vec![bs.to_string()];
        let mut grow = Vec::new();
        for m in &models {
            let base = ppl_point(ctx, m, &base_cfg, 8)?;
            let q = ppl_point(ctx, m, &qcfg, bs)?;
            row.push(format!("{:+.3}", q - base));
            grow.push(q - base);
        }
        t.row(row);
        gaps.push(grow);
    }
    let mut verdicts = String::new();
    for (j, name) in MODELS.iter().enumerate() {
        // inversion = the gap at the smallest bs exceeds the minimum gap
        let col: Vec<f64> = gaps.iter().map(|r| r[j]).collect();
        let min = col.iter().cloned().fold(f64::INFINITY, f64::min);
        let inverted = col[0] > min * 1.02 + 2e-3;
        verdicts.push_str(&format!(
            "  {name}: {}\n",
            if inverted {
                "perplexity INVERSION at small bs"
            } else {
                "monotone (no inversion in range)"
            }
        ));
    }
    Ok(format!("{}{verdicts}", t.render()))
}

/// Fig. 4(b,c): ppl vs bs with UE5M3 vs UE4M3 / UE4M3-S on two models.
pub fn fig4bc(ctx: &mut Ctx) -> Result<String> {
    let models = ensure_models(ctx)?;
    let sweep = block_sweep(ctx);
    let mut out = String::new();
    for want in ["granite-like", "llama3-like"] {
        let m = models.iter().find(|m| m.name == want).unwrap();
        let mut t = Table::new(
            &format!("Figure 4(b/c): perplexity vs block size — {want}"),
            &["block size", "UE4M3", "UE4M3-S", "UE5M3 (ours)", "BF16 base"],
        );
        let base = ppl_point(ctx, m, &QConfig::baseline(), 8)?;
        for &bs in &sweep {
            t.row(vec![
                bs.to_string(),
                format!("{:.3}", ppl_point(ctx, m, &QConfig::fp4("ue4m3")?, bs)?),
                format!(
                    "{:.3}",
                    ppl_point(
                        ctx,
                        m,
                        &QConfig::fp4("ue4m3")?.with_per_tensor(true),
                        bs
                    )?
                ),
                format!("{:.3}", ppl_point(ctx, m, &QConfig::fp4("ue5m3")?, bs)?),
                format!("{base:.3}"),
            ]);
        }
        out.push_str(&t.render());
    }
    Ok(out)
}

/// Fig. 5(a): the fig-1(b) data on a log-gap scale (dominant inversions).
pub fn fig5a(ctx: &mut Ctx) -> Result<String> {
    let models = ensure_models(ctx)?;
    let sweep = block_sweep(ctx);
    let qcfg = QConfig::fp4("ue4m3")?;
    let mut t = Table::new(
        "Figure 5(a): log10 perplexity gap vs block size (FP4+UE4M3)",
        &[&["block size"][..], &MODELS].concat(),
    );
    for &bs in &sweep {
        let mut row = vec![bs.to_string()];
        for m in &models {
            let base = ppl_point(ctx, m, &QConfig::baseline(), 8)?;
            let q = ppl_point(ctx, m, &qcfg, bs)?;
            let gap = (q - base).max(1e-6);
            row.push(format!("{:.2}", gap.log10()));
        }
        t.row(row);
    }
    Ok(t.render())
}

/// Fig. 5(b): inversion emerging at bs 2/4 even for the wide model.
pub fn fig5b(ctx: &mut Ctx) -> Result<String> {
    let models = ensure_models(ctx)?;
    let m = models.iter().find(|m| m.name == "llama2-like").unwrap();
    let qcfg = QConfig::fp4("ue4m3")?;
    let base = ppl_point(ctx, m, &QConfig::baseline(), 8)?;
    let mut t = Table::new(
        "Figure 5(b): llama2-like at tiny block sizes (FP4+UE4M3)",
        &["block size", "ppl gap"],
    );
    let mut col = Vec::new();
    for bs in [2usize, 4, 8, 16, 32] {
        let q = ppl_point(ctx, m, &qcfg, bs)?;
        t.row(vec![bs.to_string(), format!("{:+.3}", q - base)]);
        col.push(q - base);
    }
    let min = col.iter().cloned().fold(f64::INFINITY, f64::min);
    Ok(format!(
        "{}  inversion at bs 2/4: {}\n",
        t.render(),
        if col[0] > min * 1.02 + 2e-3 { "YES (paper: emerges at bs 2-4)" } else { "no" }
    ))
}

/// Fig. 14: INT4 elements — UE4M3 / UE4M3-S / UE5M3.
pub fn fig14(ctx: &mut Ctx) -> Result<String> {
    let models = ensure_models(ctx)?;
    let sweep: Vec<usize> =
        if ctx.fast { vec![2, 8, 32] } else { vec![2, 4, 8, 16, 32] };
    let mut out = String::new();
    for want in ["granite-like", "llama3-like"] {
        let m = models.iter().find(|m| m.name == want).unwrap();
        let base = ppl_point(ctx, m, &QConfig::baseline(), 8)?;
        let mut t = Table::new(
            &format!("Figure 14: INT4 microscaling — {want} (BF16 base {base:.3})"),
            &["block size", "UE4M3", "UE4M3-S", "UE5M3 (ours)"],
        );
        for &bs in &sweep {
            t.row(vec![
                bs.to_string(),
                format!(
                    "{:.3}",
                    ppl_point(ctx, m, &QConfig::named("int4", "ue4m3", false)?, bs)?
                ),
                format!(
                    "{:.3}",
                    ppl_point(ctx, m, &QConfig::named("int4", "ue4m3", true)?, bs)?
                ),
                format!(
                    "{:.3}",
                    ppl_point(ctx, m, &QConfig::named("int4", "ue5m3", false)?, bs)?
                ),
            ]);
        }
        out.push_str(&t.render());
    }
    Ok(out)
}

/// Fig. 16: UE4M3 vs UE4M3-S vs UE5M3 across the model suite.
pub fn fig16(ctx: &mut Ctx) -> Result<String> {
    let models = ensure_models(ctx)?;
    let sweep = block_sweep(ctx);
    let mut out = String::new();
    for m in &models {
        let base = ppl_point(ctx, m, &QConfig::baseline(), 8)?;
        let mut t = Table::new(
            &format!("Figure 16: {} (BF16 base {base:.3})", m.name),
            &["block size", "UE4M3", "UE4M3-S", "UE5M3 (ours)"],
        );
        for &bs in &sweep {
            t.row(vec![
                bs.to_string(),
                format!("{:.3}", ppl_point(ctx, m, &QConfig::fp4("ue4m3")?, bs)?),
                format!(
                    "{:.3}",
                    ppl_point(ctx, m, &QConfig::fp4("ue4m3")?.with_per_tensor(true), bs)?
                ),
                format!("{:.3}", ppl_point(ctx, m, &QConfig::fp4("ue5m3")?, bs)?),
            ]);
        }
        out.push_str(&t.render());
    }
    Ok(out)
}

/// Fig. 17: the UE4M4 alternative repurposing (App. J).
pub fn fig17(ctx: &mut Ctx) -> Result<String> {
    let models = ensure_models(ctx)?;
    let sweep = block_sweep(ctx);
    let mut out = String::new();
    for want in ["granite-like", "llama3-like"] {
        let m = models.iter().find(|mm| mm.name == want).unwrap();
        let base = ppl_point(ctx, m, &QConfig::baseline(), 8)?;
        let mut t = Table::new(
            &format!("Figure 17: UE4M4 repurposing — {want}"),
            &["block size", "UE4M3 gap", "UE4M4 gap", "UE5M3 gap"],
        );
        for &bs in &sweep {
            let mut g = |scale: &str| -> Result<String> {
                Ok(format!(
                    "{:+.3}",
                    ppl_point(ctx, m, &QConfig::fp4(scale)?, bs)? - base
                ))
            };
            t.row(vec![bs.to_string(), g("ue4m3")?, g("ue4m4")?, g("ue5m3")?]);
        }
        out.push_str(&t.render());
    }
    Ok(out)
}

/// Tables 1 (bs 8) and 3 (bs 16): perplexity + downstream probes per
/// format across the model suite.
pub fn table1or3(ctx: &mut Ctx, bs: usize) -> Result<String> {
    let models = ensure_models(ctx)?;
    let formats: [(&str, QConfig); 4] = [
        ("BF16", QConfig::baseline()),
        ("UE4M3", QConfig::fp4("ue4m3")?),
        ("UE4M3-S", QConfig::fp4("ue4m3")?.with_per_tensor(true)),
        ("UE5M3 (ours)", QConfig::fp4("ue5m3")?),
    ];
    let nb = if ctx.fast { 1 } else { 3 };
    let steps = if ctx.fast { 60 } else { ctx.train_steps };
    let mut t = Table::new(
        &format!(
            "Table {}: accuracy probes at block size {bs} (synthetic substitutes — see DESIGN.md §1)",
            if bs == 8 { "1" } else { "3" }
        ),
        &["model", "format", "SynPPL ↓", "Top1 ↑", "Top5 ↑", "PrefAcc ↑", "KL→BF16 ↓"],
    );
    for m in &models {
        for (label, qcfg) in &formats {
            let ppl = ppl_point(ctx, m, qcfg, bs)?;
            let key = format!(
                "probes/s{steps}/{}/{}/bs{bs}/pb{nb}/seed{PROBE_SEED}",
                m.name,
                qcfg.id()
            );
            let v = ctx.cached(&key, |c| {
                let sess = c.session()?;
                let corpus =
                    Corpus::default_language(sess.manifest().model.vocab);
                let r = eval::probes_for_config(
                    sess,
                    m.dev(c)?,
                    &corpus,
                    qcfg,
                    bs,
                    nb,
                    PROBE_SEED,
                )?;
                Ok(crate::util::json::obj(vec![
                    ("top1", num(r.top1)),
                    ("top5", num(r.top5)),
                    ("pref", num(r.pref_acc)),
                    ("kl", num(r.kl_to_baseline)),
                ]))
            })?;
            t.row(vec![
                m.name.clone(),
                label.to_string(),
                format!("{ppl:.3}"),
                format!("{:.2}", v.get("top1")?.as_f64()?),
                format!("{:.2}", v.get("top5")?.as_f64()?),
                format!("{:.2}", v.get("pref")?.as_f64()?),
                format!("{:.4}", v.get("kl")?.as_f64()?),
            ]);
        }
    }
    Ok(t.render())
}

/// Table 2: FP6 scale formats (App. H) on the llama3-like model.
pub fn table2(ctx: &mut Ctx) -> Result<String> {
    let models = ensure_models(ctx)?;
    let m = models.iter().find(|m| m.name == "llama3-like").unwrap();
    let base = ppl_point(ctx, m, &QConfig::baseline(), 8)?;
    let sweep = block_sweep(ctx);
    let mut t = Table::new(
        &format!(
            "Table 2: FP4 elements with FP6 scales — llama3-like (BF16 base {base:.3})"
        ),
        &["block size", "UE5M1", "UE5M1-S", "UE4M2", "UE4M2-S"],
    );
    for &bs in &sweep {
        let mut p = |scale: &str, pt: bool| -> Result<String> {
            Ok(format!(
                "{:.3}",
                ppl_point(
                    ctx,
                    m,
                    &QConfig::named("fp4_e2m1", scale, pt)?,
                    bs
                )?
            ))
        };
        t.row(vec![
            bs.to_string(),
            p("ue5m1", false)?,
            p("ue5m1", true)?,
            p("ue4m2", false)?,
            p("ue4m2", true)?,
        ]);
    }
    Ok(t.render())
}

/// Export a machine-readable summary of all cached ppl points (CSV).
pub fn export_csv(ctx: &mut Ctx) -> Result<()> {
    let mut rows = Vec::new();
    // cache keys are "ppl/s{steps}/{model}/{cfg}/bs{bs}/eb{n}/seed{s}"
    let keys: Vec<String> = {
        // snapshot of keys via a JSON round-trip of the cache file
        let path = ctx.results_dir.join("cache.json");
        if !path.exists() {
            return Ok(());
        }
        let j = Json::parse(&std::fs::read_to_string(path)?)?;
        j.as_obj()?
            .iter()
            .filter(|(k, _)| k.starts_with("ppl/"))
            .map(|(k, _)| k.clone())
            .collect()
    };
    for k in keys {
        if let Some(v) = ctx.cache.get(&k) {
            rows.push(vec![k.clone(), format!("{}", v.as_f64().unwrap_or(f64::NAN))]);
        }
    }
    ctx.sink()?.csv("ppl_points", &["key", "perplexity"], &rows)?;
    Ok(())
}
