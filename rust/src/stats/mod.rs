//! Statistics helpers: moments, MSE, χ² agreement, 2-D histograms
//! (Fig. 2(a) density plots), and series utilities.

/// Mean of f64 slice.
pub fn mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().sum::<f64>() / x.len() as f64
}

/// Population standard deviation.
pub fn std_dev(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    let m = mean(x);
    (x.iter().map(|&v| (v - m) * (v - m)).sum::<f64>() / x.len() as f64)
        .sqrt()
}

/// Population standard deviation of an f32 tensor (f64 accumulation).
pub fn std_dev_f32(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    let n = x.len() as f64;
    let mut s = 0.0f64;
    let mut ss = 0.0f64;
    for &v in x {
        let v = v as f64;
        s += v;
        ss += v * v;
    }
    let m = s / n;
    (ss / n - m * m).max(0.0).sqrt()
}

/// Mean squared error between two f32 tensors (f64 accumulation).
pub fn mse_f32(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let d = (x - y) as f64;
        acc += d * d;
    }
    acc / a.len() as f64
}

/// χ² agreement metric used by the paper to compare theory vs experiment
/// (Sec. 4.2/4.3 quote χ² ≈ 2e-9 .. 1.3e-6): sum of squared residuals in
/// log10-space normalized by the number of points — insensitive to the
/// absolute MSE magnitude, like the paper's log-log plots.
pub fn chi2_log(theory: &[f64], experiment: &[f64]) -> f64 {
    assert_eq!(theory.len(), experiment.len());
    let mut acc = 0.0;
    let mut n = 0usize;
    for (&t, &e) in theory.iter().zip(experiment) {
        if t > 0.0 && e > 0.0 {
            let d = t.log10() - e.log10();
            acc += d * d;
            n += 1;
        }
    }
    if n == 0 {
        f64::INFINITY
    } else {
        acc / n as f64
    }
}

/// Plain relative χ²: Σ ((t-e)/t)² / n over positive theory points.
pub fn chi2_rel(theory: &[f64], experiment: &[f64]) -> f64 {
    assert_eq!(theory.len(), experiment.len());
    let mut acc = 0.0;
    let mut n = 0usize;
    for (&t, &e) in theory.iter().zip(experiment) {
        if t > 0.0 {
            let d = (t - e) / t;
            acc += d * d;
            n += 1;
        }
    }
    if n == 0 {
        f64::INFINITY
    } else {
        acc / n as f64
    }
}

/// Nearest-rank percentile: sorts `samples` in place and returns the
/// value at index `round((n-1)·p/100)`; `0.0` for an empty slice.
///
/// This is the one percentile rule every serving statistic in the repo
/// uses ([`crate::serve::ServeStats`], `BENCH_decode.json`,
/// `BENCH_kv.json`, `BENCH_traffic.json`), so p50/p95/p99 numbers are
/// comparable across reports. The index rule means n = 1 returns the
/// only sample for every p, and an exact quantile hit (e.g. p50 over
/// an odd n) reads the middle element rather than interpolating.
pub fn percentile(samples: &mut [f64], p: f64) -> f64 {
    percentiles(samples, [p])[0]
}

/// [`percentile`] over many quantiles with a single sort.
pub fn percentiles<const N: usize>(
    samples: &mut [f64],
    ps: [f64; N],
) -> [f64; N] {
    if samples.is_empty() {
        return [0.0; N];
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    ps.map(|p| {
        let idx = ((samples.len() - 1) as f64 * p / 100.0).round() as usize;
        samples[idx.min(samples.len() - 1)]
    })
}

/// Log-spaced grid in [lo, hi] (inclusive), like numpy.geomspace.
pub fn geomspace(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    assert!(lo > 0.0 && hi > lo && n >= 2);
    let (a, b) = (lo.ln(), hi.ln());
    (0..n)
        .map(|i| (a + (b - a) * i as f64 / (n - 1) as f64).exp())
        .collect()
}

/// A 2-D histogram over log10-log10 space (Fig. 2(a)/Fig. 6 density).
#[derive(Debug, Clone)]
pub struct Histogram2d {
    pub bins: usize,
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
    pub dropped: u64,
}

impl Histogram2d {
    pub fn new(bins: usize, lo: f64, hi: f64) -> Self {
        Histogram2d {
            bins,
            lo,
            hi,
            counts: vec![0; bins * bins],
            total: 0,
            dropped: 0,
        }
    }

    pub fn add(&mut self, x: f64, y: f64) {
        if !(x > 0.0 && y > 0.0) {
            self.dropped += 1;
            return;
        }
        let fx = (x.log10() - self.lo) / (self.hi - self.lo);
        let fy = (y.log10() - self.lo) / (self.hi - self.lo);
        if !(0.0..1.0).contains(&fx) || !(0.0..1.0).contains(&fy) {
            self.dropped += 1;
            return;
        }
        let ix = (fx * self.bins as f64) as usize;
        let iy = (fy * self.bins as f64) as usize;
        self.counts[iy * self.bins + ix] += 1;
        self.total += 1;
    }

    /// Fraction of mass strictly above the diagonal (y > x).
    pub fn above_diagonal(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let mut above = 0u64;
        for iy in 0..self.bins {
            for ix in 0..self.bins {
                if iy > ix {
                    above += self.counts[iy * self.bins + ix];
                }
            }
        }
        above as f64 / self.total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments() {
        let x = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&x), 2.5);
        assert!((std_dev(&x) - 1.118033988749895).abs() < 1e-12);
        let f: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        assert!((std_dev_f32(&f) - 1.118033988749895).abs() < 1e-6);
    }

    #[test]
    fn chi2_zero_for_identical() {
        let t = [1e-6, 2e-5, 3e-4];
        assert_eq!(chi2_log(&t, &t), 0.0);
        assert_eq!(chi2_rel(&t, &t), 0.0);
        let e = [1.1e-6, 2.2e-5, 3.3e-4];
        assert!(chi2_log(&t, &e) > 0.0);
    }

    #[test]
    fn percentile_boundary_indices() {
        // n = 0: every quantile is 0.0
        assert_eq!(percentile(&mut [], 50.0), 0.0);
        assert_eq!(percentiles(&mut [], [50.0, 99.0]), [0.0, 0.0]);
        // n = 1: the only sample, for every p
        assert_eq!(percentile(&mut [7.5], 0.0), 7.5);
        assert_eq!(percentile(&mut [7.5], 50.0), 7.5);
        assert_eq!(percentile(&mut [7.5], 100.0), 7.5);
        // n = 2: round((1)·p/100) — p < 50 reads [0], p ≥ 50 reads [1]
        // (round-half-away-from-zero puts the tie at the upper sample)
        assert_eq!(percentile(&mut [3.0, 1.0], 0.0), 1.0);
        assert_eq!(percentile(&mut [3.0, 1.0], 49.0), 1.0);
        assert_eq!(percentile(&mut [3.0, 1.0], 50.0), 3.0);
        assert_eq!(percentile(&mut [3.0, 1.0], 100.0), 3.0);
        // exact quantile hits: 5 samples, p50 is the middle element and
        // p25/p75 land on indices 1 and 3 exactly
        let mut x = [50.0, 10.0, 40.0, 20.0, 30.0];
        assert_eq!(percentiles(&mut x, [25.0, 50.0, 75.0]), [20.0, 30.0, 40.0]);
        // one sort serves every quantile, input order irrelevant
        let mut a = [9.0, 2.0, 5.0, 7.0];
        let mut b = [2.0, 5.0, 7.0, 9.0];
        assert_eq!(
            percentiles(&mut a, [0.0, 95.0, 100.0]),
            percentiles(&mut b, [0.0, 95.0, 100.0])
        );
    }

    #[test]
    fn geomspace_endpoints() {
        let g = geomspace(1e-4, 1.0, 9);
        assert!((g[0] - 1e-4).abs() < 1e-12);
        assert!((g[8] - 1.0).abs() < 1e-12);
        assert!(g.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn histogram_diagonal() {
        let mut h = Histogram2d::new(32, -8.0, 0.0);
        h.add(1e-4, 1e-2); // above diagonal
        h.add(1e-2, 1e-4); // below
        h.add(0.0, 1e-3); // dropped
        assert_eq!(h.total, 2);
        assert_eq!(h.dropped, 1);
        assert!((h.above_diagonal() - 0.5).abs() < 1e-12);
    }
}
