//! Storage / bandwidth model (Sec. 3.1).
//!
//! "memory requirements for a format with N 4-bit elements per block and
//! 16-bit scales are 1/2 + 2/N bytes and every halving of block size
//! increases storage by a factor of 4/(N+4)."

/// Bytes per element for `elem_bits`-bit elements sharing a
/// `scale_bits`-bit scale over blocks of N.
pub fn bytes_per_element(elem_bits: u32, scale_bits: u32, n: usize) -> f64 {
    elem_bits as f64 / 8.0 + scale_bits as f64 / 8.0 / n as f64
}

/// Relative storage increase when halving the block size N → N/2
/// (paper: +4/(N+4) for 4-bit elems + 16-bit scales).
pub fn halving_overhead(elem_bits: u32, scale_bits: u32, n: usize) -> f64 {
    bytes_per_element(elem_bits, scale_bits, n / 2)
        / bytes_per_element(elem_bits, scale_bits, n)
        - 1.0
}

/// Compression ratio vs 16-bit baseline storage.
pub fn compression_vs_bf16(elem_bits: u32, scale_bits: u32, n: usize) -> f64 {
    2.0 / bytes_per_element(elem_bits, scale_bits, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_storage_formula() {
        for n in [8usize, 16, 32, 256] {
            assert!(
                (bytes_per_element(4, 16, n) - (0.5 + 2.0 / n as f64)).abs()
                    < 1e-12
            );
            // paper: halving N increases storage by 4/(N+4)
            assert!(
                (halving_overhead(4, 16, n) - 4.0 / (n as f64 + 4.0)).abs()
                    < 1e-12,
                "N={n}"
            );
        }
    }

    #[test]
    fn fp8_scales_compress_better() {
        assert!(
            compression_vs_bf16(4, 8, 16) > compression_vs_bf16(4, 16, 16)
        );
        // MXFP4-with-FP8-scale at N=32: 0.53125 B/elem → ~3.76x vs bf16
        let c = compression_vs_bf16(4, 8, 32);
        assert!((c - 2.0 / (0.5 + 1.0 / 32.0)).abs() < 1e-12);
    }
}
