//! Storage / bandwidth model (Sec. 3.1).
//!
//! "memory requirements for a format with N 4-bit elements per block and
//! 16-bit scales are 1/2 + 2/N bytes and every halving of block size
//! increases storage by a factor of 4/(N+4)."

/// Bytes per element for `elem_bits`-bit elements sharing a
/// `scale_bits`-bit scale over blocks of N.
pub fn bytes_per_element(elem_bits: u32, scale_bits: u32, n: usize) -> f64 {
    elem_bits as f64 / 8.0 + scale_bits as f64 / 8.0 / n as f64
}

/// Relative storage increase when halving the block size N → N/2
/// (paper: +4/(N+4) for 4-bit elems + 16-bit scales).
pub fn halving_overhead(elem_bits: u32, scale_bits: u32, n: usize) -> f64 {
    bytes_per_element(elem_bits, scale_bits, n / 2)
        / bytes_per_element(elem_bits, scale_bits, n)
        - 1.0
}

/// Compression ratio vs 16-bit baseline storage.
pub fn compression_vs_bf16(elem_bits: u32, scale_bits: u32, n: usize) -> f64 {
    2.0 / bytes_per_element(elem_bits, scale_bits, n)
}

/// Exact payload bytes of a **materialized** packed MX tensor
/// ([`crate::quant::packed::PackedMxTensor`]): the bit-packed element
/// field rounded up to whole bytes, plus one scale byte per block — a
/// trailing partial block still needs its own scale byte.
///
/// Where a tensor actually exists in memory this replaces the analytic
/// [`bytes_per_element`] estimate (which ignores byte rounding and
/// assumes 16-bit scales); the two agree in the limit — see the tests.
pub fn packed_payload_bytes(elem_bits: u32, numel: usize, block: usize) -> usize {
    (numel * elem_bits as usize + 7) / 8 + numel.div_ceil(block.max(1))
}

/// Measured bytes/element of the packed layout (8-bit scale codes).
pub fn packed_bytes_per_element(elem_bits: u32, numel: usize, block: usize) -> f64 {
    if numel == 0 {
        return 0.0;
    }
    packed_payload_bytes(elem_bits, numel, block) as f64 / numel as f64
}

/// f32 KV-cache bytes one decoded position holds resident: a `d_model`
/// key row and value row per layer at 4 bytes each — the storage cost
/// of the serving path's `Exact` KV codec
/// ([`crate::serve::kvpool`]), and the per-token figure the serve/decode
/// bench reports price memory with.
pub fn kv_exact_position_bytes(d_model: usize, n_layers: usize) -> usize {
    2 * n_layers * d_model * 4
}

/// Packed MX KV-cache bytes per position: per row, a bit-packed
/// `elem_bits`-wide code field (rounded up to whole bytes) plus
/// `scale_bytes` per `block`-wide block — exactly the
/// [`crate::serve::kvpool`] `Mx` page row layout (1-byte scale codes
/// for UE4M3/UE5M3/E8M0-class formats, 4 for quasi-continuous BF16).
pub fn kv_packed_position_bytes(
    d_model: usize,
    n_layers: usize,
    elem_bits: u32,
    scale_bytes: usize,
    block: usize,
) -> usize {
    let row = (d_model * elem_bits as usize + 7) / 8
        + d_model.div_ceil(block.max(1)) * scale_bytes;
    2 * n_layers * row
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_storage_formula() {
        for n in [8usize, 16, 32, 256] {
            assert!(
                (bytes_per_element(4, 16, n) - (0.5 + 2.0 / n as f64)).abs()
                    < 1e-12
            );
            // paper: halving N increases storage by 4/(N+4)
            assert!(
                (halving_overhead(4, 16, n) - 4.0 / (n as f64 + 4.0)).abs()
                    < 1e-12,
                "N={n}"
            );
        }
    }

    #[test]
    fn packed_layout_matches_analytic_model() {
        // whole-byte element fields: measured == analytic with 8-bit scales
        for (bits, bs) in [(4u32, 8usize), (4, 32), (8, 16)] {
            let n = bs * 100;
            assert_eq!(
                packed_bytes_per_element(bits, n, bs),
                bytes_per_element(bits, 8, bs),
                "bits={bits} bs={bs}"
            );
        }
        // 6-bit elements, element count NOT a multiple of 4: the bit
        // field is not byte-aligned, so the +7 round-up must fire.
        // 10 elements * 6 bits = 60 bits -> 8 bytes (7 if truncated),
        // plus 5 scale bytes at block size 2.
        assert_eq!(packed_payload_bytes(6, 10, 2), 8 + 5);
        // byte-aligned 6-bit case collapses onto the analytic model
        let n = 16 * 100;
        let meas = packed_bytes_per_element(6, n, 16);
        let analytic = bytes_per_element(6, 8, 16);
        assert!((meas - analytic).abs() < 1e-15);
        assert_eq!(packed_payload_bytes(4, 64, 8), 32 + 8);
        // a trailing partial block still carries a scale byte
        assert_eq!(packed_payload_bytes(4, 12, 8), 6 + 2);
        assert_eq!(packed_bytes_per_element(4, 0, 8), 0.0);
    }

    #[test]
    fn kv_position_costs_match_the_page_layout() {
        // llama-8B-ish shape: FP4 bs32 KV is ~7.5x smaller than f32
        let (d, l) = (4096usize, 32usize);
        assert_eq!(kv_exact_position_bytes(d, l), 1_048_576);
        assert_eq!(kv_packed_position_bytes(d, l, 4, 1, 32), 139_264);
        assert_eq!(kv_packed_position_bytes(d, l, 8, 1, 32), 270_336);
        let ratio = kv_exact_position_bytes(d, l) as f64
            / kv_packed_position_bytes(d, l, 4, 1, 32) as f64;
        assert!((ratio - 7.529).abs() < 1e-2, "{ratio}");
        // bf16-class scales pay 4 bytes per block
        assert_eq!(
            kv_packed_position_bytes(64, 1, 4, 4, 16),
            2 * (32 + 4 * 4)
        );
    }

    #[test]
    fn fp8_scales_compress_better() {
        assert!(
            compression_vs_bf16(4, 8, 16) > compression_vs_bf16(4, 16, 16)
        );
        // MXFP4-with-FP8-scale at N=32: 0.53125 B/elem → ~3.76x vs bf16
        let c = compression_vs_bf16(4, 8, 32);
        assert!((c - 2.0 / (0.5 + 1.0 / 32.0)).abs() < 1e-12);
    }
}
