//! Hardware cost model (Fig. 4(a), Sec. 3.1, App. K).
//!
//! The paper's hardware claim is *relative*: adding one exponent bit to
//! the microscaling-FP4 scale datapath (UE4M3 → UE5M3) of a
//! multi-precision SIMD PE (Agrawal et al. 2021-style: BF16, FP8 E4M3 /
//! E5M2, INT8, MXFP4 pipelines + staging/register file) costs ≈0.5% area
//! and ≈4 ps of critical path, because the extra bit is diluted by
//! everything else. [`pe`] reproduces that dilution argument with a
//! transparent unit-gate model; [`memory`] implements the Sec. 3.1
//! storage/complexity formulas.

pub mod memory;
pub mod pe;
