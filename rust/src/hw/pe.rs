//! Unit-gate area/timing model of the multi-precision MAC processing
//! engine (App. K).
//!
//! Area unit: NAND2-equivalent gates (GE). Standard structural estimates:
//!
//! * ripple/compressor array multiplier n×m: ~6·n·m GE
//! * adder n bits: ~7·n GE (incl. carry logic)
//! * barrel shifter n bits × log2(n) stages: ~3·n·log2(n) GE
//! * 2:1 mux n bits: ~3·n GE; register bit: ~6 GE
//!
//! Timing unit: picoseconds at a nominal 4 nm-ish 15 ps/FO4; adder delay
//! modeled as carry-lookahead ~ (2·log2(n)+4) FO4.
//!
//! These constants are conventional textbook figures — the *claim* under
//! test is relative (Δarea, Δdelay between the E4M3- and E5M3-scale PE
//! variants), which is insensitive to the absolute calibration.

/// Gate-equivalents of structural blocks.
pub fn mult_ge(n: u32, m: u32) -> f64 {
    6.0 * n as f64 * m as f64
}

pub fn adder_ge(n: u32) -> f64 {
    7.0 * n as f64
}

pub fn shifter_ge(n: u32) -> f64 {
    let stages = (n as f64).log2().ceil().max(1.0);
    3.0 * n as f64 * stages
}

pub fn mux_ge(n: u32) -> f64 {
    3.0 * n as f64
}

pub fn regs_ge(bits: u32) -> f64 {
    6.0 * bits as f64
}

const FO4_PS: f64 = 15.0;

/// Carry-lookahead adder delay in ps (smooth log2: a 4b->5b widening
/// costs a fraction of a stage, not a full one).
pub fn adder_delay_ps(n: u32) -> f64 {
    (2.0 * (n as f64).log2() + 4.0) * FO4_PS
}

/// A scale format's datapath parameters.
#[derive(Debug, Clone, Copy)]
pub struct ScaleFmt {
    pub name: &'static str,
    pub e_bits: u32,
    /// mantissa bits including the implied 1 (paper Sec. 3.1's M)
    pub m_bits_incl: u32,
}

pub const SCALE_E4M3: ScaleFmt = ScaleFmt { name: "ue4m3", e_bits: 4, m_bits_incl: 4 };
pub const SCALE_E5M3: ScaleFmt = ScaleFmt { name: "ue5m3", e_bits: 5, m_bits_incl: 4 };
pub const SCALE_E4M4: ScaleFmt = ScaleFmt { name: "ue4m4", e_bits: 4, m_bits_incl: 5 };
pub const SCALE_BF16: ScaleFmt = ScaleFmt { name: "bf16", e_bits: 8, m_bits_incl: 8 };

/// Area breakdown of one SIMD lane (GE).
#[derive(Debug, Clone)]
pub struct LaneArea {
    pub bf16_pipe: f64,
    pub fp8_pipe: f64,
    pub int8_pipe: f64,
    pub mxfp4_products: f64,
    pub mxfp4_scale_path: f64,
    pub accum: f64,
    pub staging: f64,
}

impl LaneArea {
    pub fn total(&self) -> f64 {
        self.bf16_pipe
            + self.fp8_pipe
            + self.int8_pipe
            + self.mxfp4_products
            + self.mxfp4_scale_path
            + self.accum
            + self.staging
    }
}

/// MAC terms per lane (the engine multiplies several weight/input pairs
/// per instruction, per Agrawal et al.).
pub const MAC_TERMS: u32 = 8;
/// inter-PE partial-sum width (paper's K in the M²·K complexity note)
pub const PSUM_MANTISSA: u32 = 24;
pub const PSUM_EXP: u32 = 8;

/// Model one SIMD lane of the PE for a given MXFP4 scale format.
pub fn lane_area(scale: ScaleFmt) -> LaneArea {
    let t = MAC_TERMS as f64;
    // BF16 FMA pipeline: 8x8 mantissa mult per term + exponent add +
    // align/normalize shifters
    let bf16_pipe = t * (mult_ge(8, 8) + adder_ge(8) + shifter_ge(24))
        + shifter_ge(24)
        + adder_ge(24);
    // FP8 (E4M3/E5M2 shared datapath): 4x4 mult + 5b exp add + align
    let fp8_pipe = t * (mult_ge(4, 4) + adder_ge(5) + shifter_ge(16))
        + adder_ge(16);
    // INT8: 8x8 mult + 18b accumulate
    let int8_pipe = t * mult_ge(8, 8) + adder_ge(18);
    // MXFP4 products: E2M1 elements: 2x2 mantissa mult (trivial) + 3b exp
    // add per term, then a small adder tree over the terms
    let mxfp4_products =
        t * (mult_ge(2, 2) + adder_ge(3)) + (t - 1.0) * adder_ge(8);
    // MXFP4 scale path (the part UE5M3 touches — Fig. 4(a)):
    //   mantissa: M×M mult of the two block scales, fused into the
    //   product sum: M × PSUM multiplier contribution (Sec. 3.1: M²K
    //   complexity enters through this fusion)
    //   exponent: e_bits adder for the scale-exponent sum + subtract
    //   from the 8b partial-sum exponent (width unchanged, App. K)
    let m = scale.m_bits_incl;
    // scale operand staging: weight + activation scale per instruction,
    // (e + m) bits wide, held across the 4-stage MAC pipeline
    let scale_regs = regs_ge(2 * (scale.e_bits + m) * 4);
    let mxfp4_scale_path = mult_ge(m, m)
        + mult_ge(m, PSUM_MANTISSA) / 4.0 // fused rescale of the psum
        + adder_ge(scale.e_bits)
        + adder_ge(PSUM_EXP)
        + scale_regs;
    // FP32 accumulator + normalization shared across precisions
    let accum = adder_ge(PSUM_MANTISSA) + shifter_ge(PSUM_MANTISSA);
    // operand staging + local register file (dominant non-arithmetic
    // area, App. K's dilution argument)
    let staging = regs_ge(4 * 256) + mux_ge(256);
    LaneArea {
        bf16_pipe,
        fp8_pipe,
        int8_pipe,
        mxfp4_products,
        mxfp4_scale_path,
        accum,
        staging,
    }
}

/// Whole-PE area (8 SIMD lanes + control overhead).
pub fn pe_area(scale: ScaleFmt) -> f64 {
    let lane = lane_area(scale).total();
    8.0 * lane * 1.08 // +8% control/clocking overhead
}

/// Critical path of the MXFP4 scale-fusion stage (ps): exponent adder →
/// psum exponent subtract → align. Only the first adder widens with
/// e_bits (App. K: "the width of the subsequent adders/datapath remains
/// unchanged").
pub fn scale_stage_delay_ps(scale: ScaleFmt) -> f64 {
    adder_delay_ps(scale.e_bits)
        + adder_delay_ps(PSUM_EXP)
        + adder_delay_ps(PSUM_MANTISSA)
}

/// The App. K comparison: Δarea (%) and Δdelay (ps) of E5M3 vs E4M3.
pub fn appendix_k_comparison() -> (f64, f64) {
    let a4 = pe_area(SCALE_E4M3);
    let a5 = pe_area(SCALE_E5M3);
    let d4 = scale_stage_delay_ps(SCALE_E4M3);
    let d5 = scale_stage_delay_ps(SCALE_E5M3);
    (100.0 * (a5 - a4) / a4, d5 - d4)
}

/// Sec. 3.1: multiplication complexity of scale fusion grows as M²·K.
pub fn scale_mult_complexity(m_bits_incl: u32, k: u32) -> f64 {
    (m_bits_incl as f64).powi(2) * k as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e5m3_area_delta_is_negligible() {
        let (darea, ddelay) = appendix_k_comparison();
        // paper: 0.5% area, 4 ps
        assert!(darea > 0.0 && darea < 1.5, "Δarea {darea}%");
        assert!(ddelay > 0.0 && ddelay < 40.0, "Δdelay {ddelay} ps");
    }

    #[test]
    fn bf16_scales_cost_much_more_than_fp8_scales() {
        // Sec. 3.1: 16-bit scales (M=8) raise the scale-path area by ~M²
        let p8 = lane_area(SCALE_E4M3).mxfp4_scale_path;
        let p16 = lane_area(SCALE_BF16).mxfp4_scale_path;
        assert!(p16 > 2.0 * p8, "{p16} vs {p8}");
        // and the M²K law is what drives it
        assert!(
            scale_mult_complexity(8, 24) / scale_mult_complexity(4, 24)
                == 4.0
        );
    }

    #[test]
    fn ue4m4_costs_more_area_than_ue5m3() {
        // App. J: the mantissa repurposing (M²) is pricier than the
        // exponent one (linear)
        let a5 = pe_area(SCALE_E5M3);
        let a44 = pe_area(SCALE_E4M4);
        assert!(a44 > a5, "{a44} vs {a5}");
    }

    #[test]
    fn area_breakdown_dominated_by_non_scale_logic() {
        // the dilution argument: the scale path is a small slice
        let l = lane_area(SCALE_E4M3);
        assert!(l.mxfp4_scale_path / l.total() < 0.10);
    }
}
